//! A criterion-flavoured micro-bench runner (criterion itself is not in
//! the offline crate set).
//!
//! Every `rust/benches/*.rs` target is `harness = false` and drives this
//! runner: warmup, N timed samples, mean ± 95% CI, optional throughput.
//! Output is stable, grep-able rows so EXPERIMENTS.md can quote them —
//! and, through [`BenchRecorder`], machine-readable `BENCH_<suite>.json`
//! files so the perf trajectory of the repo is recorded run over run
//! (serde is not in the offline crate set; the JSON writer is
//! hand-rolled).

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

/// Configuration for a bench run.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Untimed warmup iterations.
    pub warmup_iters: u32,
    /// Timed samples.
    pub samples: u32,
    /// Iterations averaged inside one sample (for sub-µs bodies).
    pub iters_per_sample: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 3, samples: 10, iters_per_sample: 1 }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Per-iteration wall time summary, seconds.
    pub time: Summary,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<f64>,
}

impl BenchResult {
    /// Elements per second, if `elements` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e / self.time.mean)
    }

    /// Render one stable report row.
    #[must_use]
    pub fn row(&self) -> String {
        let mut s = format!(
            "bench {:<40} mean {:>12} ±{:>10} (n={})",
            self.name,
            crate::util::humanfmt::seconds(self.time.mean),
            crate::util::humanfmt::seconds(self.time.ci95),
            self.time.n,
        );
        if let Some(tp) = self.throughput() {
            s.push_str(&format!("  {:>12.3e} elem/s", tp));
        }
        s
    }
}

/// Run a benchmark body and return its timing summary.
///
/// The body receives the iteration index; its return value is
/// black-boxed so the optimizer cannot elide the work.
#[must_use]
pub fn bench<T, F: FnMut(u32) -> T>(
    name: &str,
    cfg: BenchConfig,
    mut body: F,
) -> BenchResult {
    for i in 0..cfg.warmup_iters {
        std::hint::black_box(body(i));
    }
    let mut samples = Vec::with_capacity(cfg.samples as usize);
    for s in 0..cfg.samples {
        let start = Instant::now();
        for i in 0..cfg.iters_per_sample {
            std::hint::black_box(body(s * cfg.iters_per_sample + i));
        }
        samples.push(start.elapsed().as_secs_f64() / cfg.iters_per_sample as f64);
    }
    BenchResult { name: name.to_string(), time: summarize(&samples), elements: None }
}

/// Like [`bench`], with a throughput denominator (elements per iter).
#[must_use]
pub fn bench_throughput<T, F: FnMut(u32) -> T>(
    name: &str,
    cfg: BenchConfig,
    elements: f64,
    body: F,
) -> BenchResult {
    let mut r = bench(name, cfg, body);
    r.elements = Some(elements);
    r
}

/// Print a section header for a bench group.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Collects bench rows, free-form scalars, and metadata, and writes a
/// machine-readable `BENCH_<suite>.json` so perf results survive the
/// run as a trajectory file instead of scrollback.
///
/// ```
/// use bsps::util::benchtool::{bench, BenchConfig, BenchRecorder};
///
/// let mut rec = BenchRecorder::new("demo");
/// rec.meta("p", 16);
/// let r = bench("noop", BenchConfig::default(), |_| 1 + 1);
/// rec.push(&r);
/// rec.scalar("rel_error", 0.05);
/// let json = rec.to_json();
/// assert!(json.contains("\"suite\": \"demo\""));
/// assert!(json.contains("\"noop\""));
/// ```
#[derive(Debug)]
pub struct BenchRecorder {
    suite: String,
    meta: Vec<(String, String)>,
    rows: Vec<BenchResult>,
    scalars: Vec<(String, f64)>,
}

use crate::util::json::{escape as json_escape, num as json_num};

impl BenchRecorder {
    /// A recorder for the named suite.
    #[must_use]
    pub fn new(suite: &str) -> Self {
        Self {
            suite: suite.to_string(),
            meta: Vec::new(),
            rows: Vec::new(),
            scalars: Vec::new(),
        }
    }

    /// Attach a metadata key/value (machine, parameters, git rev, …).
    pub fn meta(&mut self, key: &str, value: impl std::fmt::Display) {
        self.meta.push((key.to_string(), value.to_string()));
    }

    /// Record a bench row.
    pub fn push(&mut self, r: &BenchResult) {
        self.rows.push(r.clone());
    }

    /// Record a free-form scalar (model errors, speedups, curve points).
    pub fn scalar(&mut self, name: &str, value: f64) {
        self.scalars.push((name.to_string(), value));
    }

    /// Serialize everything as a JSON document.
    #[must_use]
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"suite\": \"{}\",\n", json_escape(&self.suite)));
        s.push_str("  \"meta\": {");
        for (i, (k, v)) in self.meta.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    \"{}\": \"{}\"", json_escape(k), json_escape(v)));
        }
        s.push_str("\n  },\n  \"benches\": [");
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"mean_seconds\": {}, \"ci95_seconds\": {}, \
                 \"samples\": {}, \"throughput_per_second\": {}}}",
                json_escape(&r.name),
                json_num(r.time.mean),
                json_num(r.time.ci95),
                r.time.n,
                r.throughput().map_or("null".to_string(), json_num),
            ));
        }
        s.push_str("\n  ],\n  \"scalars\": {");
        for (i, (k, v)) in self.scalars.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    \"{}\": {}", json_escape(k), json_num(*v)));
        }
        s.push_str("\n  }\n}\n");
        s
    }

    /// Write the JSON document to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

// ------------------------------------------------------------------
// Perf-trajectory differ: parse two BENCH_<suite>.json files and fail
// on throughput regressions (the `bsps benchdiff` subcommand + CI gate).

pub use crate::util::json::JsonValue;

use crate::util::error::{anyhow, bail, Error};

/// One benchmark row loaded back from a `BENCH_<suite>.json` file.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotBench {
    /// Benchmark name.
    pub name: String,
    /// Mean per-iteration wall time, seconds.
    pub mean_seconds: f64,
    /// Elements per second, if the bench had a throughput denominator.
    pub throughput: Option<f64>,
}

/// A perf-trajectory file ([`BenchRecorder`] output) loaded for diffing.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchSnapshot {
    /// Suite name.
    pub suite: String,
    /// Bench rows, in file order.
    pub benches: Vec<SnapshotBench>,
    /// Trajectory scalars (model rel-errors, NoC surcharge, sweep
    /// makespan/occupancy, …), in file order. Non-finite scalars
    /// (serialized as `null`) are dropped at parse.
    pub scalars: Vec<(String, f64)>,
}

impl BenchSnapshot {
    /// Parse a `BENCH_<suite>.json` document.
    pub fn parse(text: &str) -> Result<Self, Error> {
        let root = JsonValue::parse(text)?;
        let suite = root
            .get("suite")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| anyhow!("missing `suite` field"))?
            .to_string();
        let rows = match root.get("benches") {
            Some(JsonValue::Arr(rows)) => rows,
            _ => bail!("missing `benches` array"),
        };
        let mut benches = Vec::with_capacity(rows.len());
        for row in rows {
            let name = row
                .get("name")
                .and_then(JsonValue::as_str)
                .ok_or_else(|| anyhow!("bench row without `name`"))?
                .to_string();
            let mean_seconds = row
                .get("mean_seconds")
                .and_then(JsonValue::as_num)
                .ok_or_else(|| anyhow!("bench `{name}` without `mean_seconds`"))?;
            let throughput =
                row.get("throughput_per_second").and_then(JsonValue::as_num);
            benches.push(SnapshotBench { name, mean_seconds, throughput });
        }
        // `scalars` is optional (older trajectory files predate it).
        let mut scalars = Vec::new();
        if let Some(JsonValue::Obj(fields)) = root.get("scalars") {
            for (name, v) in fields {
                if let Some(x) = v.as_num() {
                    scalars.push((name.clone(), x));
                }
            }
        }
        Ok(Self { suite, benches, scalars })
    }
}

/// One bench compared across two snapshots.
#[derive(Debug, Clone)]
pub struct DiffRow {
    /// Benchmark name.
    pub name: String,
    /// Fractional throughput change, `new/old - 1` (positive = faster).
    /// Falls back to the inverse mean-time ratio when the bench has no
    /// throughput denominator.
    pub speedup: f64,
    /// Whether the slowdown exceeds the regression threshold.
    pub regressed: bool,
}

/// Compare `new` against the `old` baseline. A bench regresses when its
/// throughput fell (or, lacking a throughput denominator, its mean time
/// rose) by more than `max_regress` (e.g. `0.15` = 15%). Benches
/// present in only one snapshot are skipped — renames must not fail CI.
#[must_use]
pub fn diff_snapshots(
    old: &BenchSnapshot,
    new: &BenchSnapshot,
    max_regress: f64,
) -> Vec<DiffRow> {
    let mut rows = Vec::new();
    for n in &new.benches {
        let Some(o) = old.benches.iter().find(|o| o.name == n.name) else {
            continue;
        };
        let speedup = match (o.throughput, n.throughput) {
            (Some(old_tp), Some(new_tp)) if old_tp > 0.0 => new_tp / old_tp - 1.0,
            _ if o.mean_seconds > 0.0 => o.mean_seconds / n.mean_seconds - 1.0,
            _ => 0.0,
        };
        rows.push(DiffRow {
            name: n.name.clone(),
            speedup,
            regressed: speedup < -max_regress,
        });
    }
    rows
}

// ------------------------------------------------------------------
// Trajectory scalars: per-scalar tolerance bands.

/// Which direction of drift a trajectory scalar regresses in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BandDir {
    /// Growing is bad (model rel-errors, route surcharge, makespan).
    HigherIsWorse,
    /// Shrinking is bad (speedups, occupancy).
    LowerIsWorse,
    /// Any drift beyond the band is bad (calibration curve points).
    TwoSided,
}

/// Tolerance band for one trajectory scalar: the new value is in band
/// when its drift (in the scalar's bad direction) stays within
/// `abs + rel·|old|`.
#[derive(Debug, Clone, Copy)]
pub struct ScalarBand {
    /// Relative slack, as a fraction of the baseline's magnitude.
    pub rel: f64,
    /// Absolute slack (keeps near-zero baselines from pinning the band
    /// shut).
    pub abs: f64,
    /// Which drift direction counts as a regression.
    pub dir: BandDir,
}

/// The built-in band table, keyed by scalar-name substrings (checked in
/// order; first hit wins; `default_rel` parameterizes the fallback):
///
/// * `rel_err` / `_rel` — model-agreement errors: small absolute slack,
///   generous relative slack (they sit near zero and jitter), growth is
///   the regression;
/// * `surcharge` — NoC route surcharge: same shape;
/// * `speedup` — bigger is better; shrinking beyond the band regresses;
/// * `occupancy` — a `(0, 1]` ratio: absolute band, shrinking is bad;
/// * `replay` — recovery replay ratios (hypersteps re-executed after a
///   checkpoint resume over total): deterministic fractions in `[0, 1)`
///   that only regress by growing (a checkpoint cadence or resume-point
///   bug shows up as more replayed work);
/// * `overhead` — infrastructure tax ratios (e.g. the superstep
///   analyzer's Warn-vs-Off scalar) that sit near 1.0: growth is the
///   regression, with a wide band because they divide two noisy
///   wall-clock means;
/// * `wait` — queue waits are millisecond-scale scheduler noise with no
///   work-derived lower bound, so they get a wide absolute floor on top
///   of the loose relative band;
/// * `seconds` / `makespan` — **wall-clock** scalars: loose relative
///   band plus an absolute floor (shared CI runners are noisy, and a
///   one-off fast baseline must not ratchet the band shut), growth is
///   bad;
/// * everything else — two-sided `default_rel` drift check (covers the
///   deterministic simulated-bandwidth curve points).
#[must_use]
pub fn scalar_band_for(name: &str, default_rel: f64) -> ScalarBand {
    if name.contains("rel_err") || name.contains("_rel") {
        ScalarBand { rel: 0.5, abs: 0.02, dir: BandDir::HigherIsWorse }
    } else if name.contains("surcharge") {
        ScalarBand { rel: 0.5, abs: 1e-3, dir: BandDir::HigherIsWorse }
    } else if name.contains("speedup") {
        ScalarBand { rel: 0.5, abs: 0.3, dir: BandDir::LowerIsWorse }
    } else if name.contains("occupancy") {
        ScalarBand { rel: 0.0, abs: 0.25, dir: BandDir::LowerIsWorse }
    } else if name.contains("replay") {
        ScalarBand { rel: 0.5, abs: 0.05, dir: BandDir::HigherIsWorse }
    } else if name.contains("overhead") {
        ScalarBand { rel: 1.0, abs: 0.5, dir: BandDir::HigherIsWorse }
    } else if name.contains("wait") {
        ScalarBand { rel: 1.0, abs: 0.25, dir: BandDir::HigherIsWorse }
    } else if name.contains("seconds") || name.contains("makespan") {
        ScalarBand { rel: 1.0, abs: 0.5, dir: BandDir::HigherIsWorse }
    } else {
        ScalarBand { rel: default_rel, abs: 1e-12, dir: BandDir::TwoSided }
    }
}

/// One trajectory scalar compared across two snapshots.
#[derive(Debug, Clone)]
pub struct ScalarDiffRow {
    /// Scalar name.
    pub name: String,
    /// Baseline value.
    pub old: f64,
    /// Candidate value.
    pub new: f64,
    /// The band the comparison used.
    pub band: ScalarBand,
    /// Whether the drift left the band in the bad direction.
    pub out_of_band: bool,
}

/// Compare `new`'s trajectory scalars against the `old` baseline under
/// [`scalar_band_for`] bands. Scalars present in only one snapshot are
/// skipped (renames and newly-added scalars must not fail CI on their
/// first appearance).
#[must_use]
pub fn diff_scalars(
    old: &BenchSnapshot,
    new: &BenchSnapshot,
    default_rel: f64,
) -> Vec<ScalarDiffRow> {
    let mut rows = Vec::new();
    for (name, new_v) in &new.scalars {
        let Some(&(_, old_v)) = old.scalars.iter().find(|(n, _)| n == name) else {
            continue;
        };
        let band = scalar_band_for(name, default_rel);
        let tol = band.abs + band.rel * old_v.abs();
        let drift = new_v - old_v;
        let out_of_band = match band.dir {
            BandDir::HigherIsWorse => drift > tol,
            BandDir::LowerIsWorse => -drift > tol,
            BandDir::TwoSided => drift.abs() > tol,
        };
        rows.push(ScalarDiffRow {
            name: name.clone(),
            old: old_v,
            new: *new_v,
            band,
            out_of_band,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_samples() {
        let cfg = BenchConfig { warmup_iters: 1, samples: 5, iters_per_sample: 2 };
        let r = bench("noop", cfg, |_| 1 + 1);
        assert_eq!(r.time.n, 5);
        assert!(r.time.mean >= 0.0);
        assert!(r.throughput().is_none());
    }

    #[test]
    fn throughput_is_elements_over_mean() {
        let cfg = BenchConfig { warmup_iters: 0, samples: 3, iters_per_sample: 1 };
        let r = bench_throughput("tp", cfg, 1000.0, |_| {
            std::thread::sleep(std::time::Duration::from_micros(100));
        });
        let tp = r.throughput().unwrap();
        assert!(tp > 0.0 && tp < 1000.0 / 50e-6, "tp={tp}");
    }

    #[test]
    fn row_contains_name() {
        let cfg = BenchConfig::default();
        let r = bench("my_bench", cfg, |i| i * 2);
        assert!(r.row().contains("my_bench"));
    }

    #[test]
    fn recorder_emits_complete_json() {
        let cfg = BenchConfig { warmup_iters: 0, samples: 2, iters_per_sample: 1 };
        let mut rec = BenchRecorder::new("suite \"x\"");
        rec.meta("p", 16);
        rec.push(&bench("a", cfg, |_| ()));
        rec.push(&bench_throughput("b", cfg, 64.0, |_| ()));
        rec.scalar("rel", 0.03);
        rec.scalar("bad", f64::NAN);
        let json = rec.to_json();
        assert!(json.contains("\"suite \\\"x\\\"\""), "names are escaped");
        assert!(json.contains("\"p\": \"16\""));
        assert!(json.contains("\"name\": \"a\""));
        assert!(json.contains("\"name\": \"b\""));
        assert!(json.contains("\"rel\": 3e-2"));
        assert!(json.contains("\"bad\": null"), "non-finite floats become null");
        // Bench "a" has no throughput denominator.
        assert!(json.contains("\"throughput_per_second\": null"));
    }

    #[test]
    fn json_roundtrips_recorder_output() {
        let cfg = BenchConfig { warmup_iters: 0, samples: 2, iters_per_sample: 1 };
        let mut rec = BenchRecorder::new("suite \"x\"\nline");
        rec.meta("p", 16);
        rec.push(&bench("plain", cfg, |_| ()));
        rec.push(&bench_throughput("tp", cfg, 64.0, |_| ()));
        rec.scalar("rel", 0.03);
        rec.scalar("bad", f64::NAN);
        let snap = BenchSnapshot::parse(&rec.to_json()).unwrap();
        assert_eq!(snap.suite, "suite \"x\"\nline", "escapes decode back");
        assert_eq!(
            snap.scalars,
            vec![("rel".to_string(), 0.03)],
            "finite scalars round-trip; null (NaN) scalars are dropped"
        );
        assert_eq!(snap.benches.len(), 2);
        assert_eq!(snap.benches[0].name, "plain");
        assert!(snap.benches[0].throughput.is_none());
        let tp = &snap.benches[1];
        assert_eq!(tp.name, "tp");
        assert!(tp.throughput.unwrap() > 0.0);
        assert!(tp.mean_seconds >= 0.0);
    }

    #[test]
    fn json_parser_handles_the_grammar() {
        let v = JsonValue::parse(
            r#"{"a": [1, -2.5e3, true, false, null, "xA\n"], "b": {}}"#,
        )
        .unwrap();
        let arr = match v.get("a") {
            Some(JsonValue::Arr(items)) => items,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(arr[0], JsonValue::Num(1.0));
        assert_eq!(arr[1], JsonValue::Num(-2500.0));
        assert_eq!(arr[2], JsonValue::Bool(true));
        assert_eq!(arr[3], JsonValue::Bool(false));
        assert_eq!(arr[4], JsonValue::Null);
        assert_eq!(arr[5], JsonValue::Str("xA\n".to_string()));
        assert_eq!(v.get("b"), Some(&JsonValue::Obj(Vec::new())));
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1,]").is_err());
        assert!(JsonValue::parse("{} trailing").is_err());
    }

    fn snap(rows: &[(&str, f64, Option<f64>)]) -> BenchSnapshot {
        BenchSnapshot {
            suite: "s".to_string(),
            benches: rows
                .iter()
                .map(|(name, mean, tp)| SnapshotBench {
                    name: name.to_string(),
                    mean_seconds: *mean,
                    throughput: *tp,
                })
                .collect(),
            scalars: Vec::new(),
        }
    }

    fn scalar_snap(scalars: &[(&str, f64)]) -> BenchSnapshot {
        BenchSnapshot {
            suite: "s".to_string(),
            benches: Vec::new(),
            scalars: scalars
                .iter()
                .map(|(n, v)| (n.to_string(), *v))
                .collect(),
        }
    }

    #[test]
    fn scalar_bands_pick_direction_by_name() {
        assert_eq!(
            scalar_band_for("overlap_rel_stream", 0.15).dir,
            BandDir::HigherIsWorse
        );
        assert_eq!(scalar_band_for("sweep_speedup", 0.15).dir, BandDir::LowerIsWorse);
        assert_eq!(
            scalar_band_for("sweep_occupancy", 0.15).dir,
            BandDir::LowerIsWorse
        );
        assert_eq!(
            scalar_band_for("sweep_makespan_seconds", 0.15).dir,
            BandDir::HigherIsWorse
        );
        // Queue waits are pure scheduler noise: the wide absolute floor
        // must win over the generic wall-clock band.
        let wait = scalar_band_for("sweep_max_queue_wait_seconds", 0.15);
        assert_eq!(wait.dir, BandDir::HigherIsWorse);
        assert!(wait.abs >= 0.25, "wait scalars need a wide absolute floor");
        // The analyzer tax ratio sits near 1.0 and divides two noisy
        // means: only growth regresses, and the band must be wide.
        // Replay ratios only regress by growing, and need their own
        // (tighter) band — they are deterministic, not wall-clock noise.
        let rep = scalar_band_for("recovery_replay_ratio", 0.15);
        assert_eq!(rep.dir, BandDir::HigherIsWorse);
        assert!(rep.rel <= 0.5 && rep.abs <= 0.05, "replay band too loose");
        let ovh = scalar_band_for("analyzer_warn_overhead", 0.15);
        assert_eq!(ovh.dir, BandDir::HigherIsWorse);
        assert!(ovh.rel >= 1.0 && ovh.abs >= 0.5, "overhead band too tight");
        assert_eq!(scalar_band_for("read_bps_512", 0.15).dir, BandDir::TwoSided);
        // The heterogeneous-split scalars `bench_fig5_cannon` records
        // must land on the prediction-error and occupancy bands (both
        // values are deterministic model-vs-ledger quantities, so the
        // tight one-sided bands apply, not the generic two-sided one).
        let hetero = scalar_band_for("hetero_split_pred_rel_err", 0.15);
        assert_eq!(hetero.dir, BandDir::HigherIsWorse);
        assert!((hetero.rel - 0.5).abs() < 1e-12 && hetero.abs <= 0.02);
        let wocc = scalar_band_for("weighted_occupancy", 0.15);
        assert_eq!(wocc.dir, BandDir::LowerIsWorse);
        assert!(wocc.rel == 0.0 && (wocc.abs - 0.25).abs() < 1e-12);
    }

    #[test]
    fn diff_scalars_flags_out_of_band_drift_only_in_the_bad_direction() {
        let old = scalar_snap(&[
            ("overlap_rel_a", 0.03),
            ("sweep_speedup", 2.0),
            ("sweep_occupancy", 0.8),
            ("read_bps_512", 1000.0),
            ("gone", 1.0),
        ]);
        let new = scalar_snap(&[
            ("overlap_rel_a", 0.30),   // error blew up: out of band
            ("sweep_speedup", 2.6),    // improvement: never flagged
            ("sweep_occupancy", 0.35), // collapsed by 0.45 > 0.25 abs band
            ("read_bps_512", 1100.0),  // +10% two-sided drift, 15% band: ok
            ("fresh", 5.0),            // no baseline: skipped
        ]);
        let rows = diff_scalars(&old, &new, 0.15);
        assert_eq!(rows.len(), 4, "unmatched scalars are skipped");
        let get = |n: &str| rows.iter().find(|r| r.name == n).unwrap();
        assert!(get("overlap_rel_a").out_of_band);
        assert!(!get("sweep_speedup").out_of_band, "improvements pass");
        assert!(get("sweep_occupancy").out_of_band);
        assert!(!get("read_bps_512").out_of_band);

        // The same improvement directions, reversed, do regress.
        let worse = scalar_snap(&[("sweep_speedup", 0.4)]);
        let rows = diff_scalars(&old, &worse, 0.15);
        assert!(rows[0].out_of_band, "speedup 2.0 → 0.4 leaves the band");
        // And a two-sided scalar drifting 30% either way fails.
        let drifted = scalar_snap(&[("read_bps_512", 700.0)]);
        assert!(diff_scalars(&old, &drifted, 0.15)[0].out_of_band);
    }

    #[test]
    fn diff_flags_throughput_regressions_beyond_threshold() {
        let old = snap(&[
            ("a", 1.0, Some(1000.0)),
            ("b", 1.0, Some(1000.0)),
            ("gone", 1.0, None),
        ]);
        let new = snap(&[
            ("a", 1.0, Some(800.0)),  // -20%: regression at 15%
            ("b", 1.0, Some(900.0)),  // -10%: within budget
            ("added", 1.0, Some(1.0)), // no baseline: skipped
        ]);
        let rows = diff_snapshots(&old, &new, 0.15);
        assert_eq!(rows.len(), 2, "unmatched benches are skipped");
        let a = rows.iter().find(|r| r.name == "a").unwrap();
        assert!(a.regressed);
        assert!((a.speedup + 0.2).abs() < 1e-9);
        let b = rows.iter().find(|r| r.name == "b").unwrap();
        assert!(!b.regressed);
    }

    #[test]
    fn diff_falls_back_to_mean_time_without_throughput() {
        let old = snap(&[("t", 1.0, None)]);
        let slower = snap(&[("t", 1.3, None)]); // 30% more time
        let rows = diff_snapshots(&old, &slower, 0.15);
        assert!(rows[0].regressed, "slowdown {:.3}", rows[0].speedup);
        let faster = snap(&[("t", 0.5, None)]);
        let rows = diff_snapshots(&old, &faster, 0.15);
        assert!(!rows[0].regressed);
        assert!(rows[0].speedup > 0.9);
    }

    #[test]
    fn recorder_writes_a_file() {
        let mut rec = BenchRecorder::new("filetest");
        rec.scalar("x", 1.0);
        let path = std::env::temp_dir().join("bsps_bench_recorder_test.json");
        let path = path.to_str().unwrap().to_string();
        rec.write(&path).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back, rec.to_json());
        let _ = std::fs::remove_file(&path);
    }
}
