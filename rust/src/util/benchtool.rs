//! A criterion-flavoured micro-bench runner (criterion itself is not in
//! the offline crate set).
//!
//! Every `rust/benches/*.rs` target is `harness = false` and drives this
//! runner: warmup, N timed samples, mean ± 95% CI, optional throughput.
//! Output is stable, grep-able rows so EXPERIMENTS.md can quote them.

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

/// Configuration for a bench run.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Untimed warmup iterations.
    pub warmup_iters: u32,
    /// Timed samples.
    pub samples: u32,
    /// Iterations averaged inside one sample (for sub-µs bodies).
    pub iters_per_sample: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 3, samples: 10, iters_per_sample: 1 }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Per-iteration wall time summary, seconds.
    pub time: Summary,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<f64>,
}

impl BenchResult {
    /// Elements per second, if `elements` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e / self.time.mean)
    }

    /// Render one stable report row.
    pub fn row(&self) -> String {
        let mut s = format!(
            "bench {:<40} mean {:>12} ±{:>10} (n={})",
            self.name,
            crate::util::humanfmt::seconds(self.time.mean),
            crate::util::humanfmt::seconds(self.time.ci95),
            self.time.n,
        );
        if let Some(tp) = self.throughput() {
            s.push_str(&format!("  {:>12.3e} elem/s", tp));
        }
        s
    }
}

/// Run a benchmark body and return its timing summary.
///
/// The body receives the iteration index; its return value is
/// black-boxed so the optimizer cannot elide the work.
pub fn bench<T, F: FnMut(u32) -> T>(
    name: &str,
    cfg: BenchConfig,
    mut body: F,
) -> BenchResult {
    for i in 0..cfg.warmup_iters {
        std::hint::black_box(body(i));
    }
    let mut samples = Vec::with_capacity(cfg.samples as usize);
    for s in 0..cfg.samples {
        let start = Instant::now();
        for i in 0..cfg.iters_per_sample {
            std::hint::black_box(body(s * cfg.iters_per_sample + i));
        }
        samples.push(start.elapsed().as_secs_f64() / cfg.iters_per_sample as f64);
    }
    BenchResult { name: name.to_string(), time: summarize(&samples), elements: None }
}

/// Like [`bench`], with a throughput denominator (elements per iter).
pub fn bench_throughput<T, F: FnMut(u32) -> T>(
    name: &str,
    cfg: BenchConfig,
    elements: f64,
    body: F,
) -> BenchResult {
    let mut r = bench(name, cfg, body);
    r.elements = Some(elements);
    r
}

/// Print a section header for a bench group.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_samples() {
        let cfg = BenchConfig { warmup_iters: 1, samples: 5, iters_per_sample: 2 };
        let r = bench("noop", cfg, |_| 1 + 1);
        assert_eq!(r.time.n, 5);
        assert!(r.time.mean >= 0.0);
        assert!(r.throughput().is_none());
    }

    #[test]
    fn throughput_is_elements_over_mean() {
        let cfg = BenchConfig { warmup_iters: 0, samples: 3, iters_per_sample: 1 };
        let r = bench_throughput("tp", cfg, 1000.0, |_| {
            std::thread::sleep(std::time::Duration::from_micros(100));
        });
        let tp = r.throughput().unwrap();
        assert!(tp > 0.0 && tp < 1000.0 / 50e-6, "tp={tp}");
    }

    #[test]
    fn row_contains_name() {
        let cfg = BenchConfig::default();
        let r = bench("my_bench", cfg, |i| i * 2);
        assert!(r.row().contains("my_bench"));
    }
}
