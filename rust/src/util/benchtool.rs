//! A criterion-flavoured micro-bench runner (criterion itself is not in
//! the offline crate set).
//!
//! Every `rust/benches/*.rs` target is `harness = false` and drives this
//! runner: warmup, N timed samples, mean ± 95% CI, optional throughput.
//! Output is stable, grep-able rows so EXPERIMENTS.md can quote them —
//! and, through [`BenchRecorder`], machine-readable `BENCH_<suite>.json`
//! files so the perf trajectory of the repo is recorded run over run
//! (serde is not in the offline crate set; the JSON writer is
//! hand-rolled).

use std::time::Instant;

use crate::util::stats::{summarize, Summary};

/// Configuration for a bench run.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Untimed warmup iterations.
    pub warmup_iters: u32,
    /// Timed samples.
    pub samples: u32,
    /// Iterations averaged inside one sample (for sub-µs bodies).
    pub iters_per_sample: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 3, samples: 10, iters_per_sample: 1 }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Per-iteration wall time summary, seconds.
    pub time: Summary,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<f64>,
}

impl BenchResult {
    /// Elements per second, if `elements` was set.
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e / self.time.mean)
    }

    /// Render one stable report row.
    pub fn row(&self) -> String {
        let mut s = format!(
            "bench {:<40} mean {:>12} ±{:>10} (n={})",
            self.name,
            crate::util::humanfmt::seconds(self.time.mean),
            crate::util::humanfmt::seconds(self.time.ci95),
            self.time.n,
        );
        if let Some(tp) = self.throughput() {
            s.push_str(&format!("  {:>12.3e} elem/s", tp));
        }
        s
    }
}

/// Run a benchmark body and return its timing summary.
///
/// The body receives the iteration index; its return value is
/// black-boxed so the optimizer cannot elide the work.
pub fn bench<T, F: FnMut(u32) -> T>(
    name: &str,
    cfg: BenchConfig,
    mut body: F,
) -> BenchResult {
    for i in 0..cfg.warmup_iters {
        std::hint::black_box(body(i));
    }
    let mut samples = Vec::with_capacity(cfg.samples as usize);
    for s in 0..cfg.samples {
        let start = Instant::now();
        for i in 0..cfg.iters_per_sample {
            std::hint::black_box(body(s * cfg.iters_per_sample + i));
        }
        samples.push(start.elapsed().as_secs_f64() / cfg.iters_per_sample as f64);
    }
    BenchResult { name: name.to_string(), time: summarize(&samples), elements: None }
}

/// Like [`bench`], with a throughput denominator (elements per iter).
pub fn bench_throughput<T, F: FnMut(u32) -> T>(
    name: &str,
    cfg: BenchConfig,
    elements: f64,
    body: F,
) -> BenchResult {
    let mut r = bench(name, cfg, body);
    r.elements = Some(elements);
    r
}

/// Print a section header for a bench group.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Collects bench rows, free-form scalars, and metadata, and writes a
/// machine-readable `BENCH_<suite>.json` so perf results survive the
/// run as a trajectory file instead of scrollback.
///
/// ```
/// use bsps::util::benchtool::{bench, BenchConfig, BenchRecorder};
///
/// let mut rec = BenchRecorder::new("demo");
/// rec.meta("p", 16);
/// let r = bench("noop", BenchConfig::default(), |_| 1 + 1);
/// rec.push(&r);
/// rec.scalar("rel_error", 0.05);
/// let json = rec.to_json();
/// assert!(json.contains("\"suite\": \"demo\""));
/// assert!(json.contains("\"noop\""));
/// ```
#[derive(Debug)]
pub struct BenchRecorder {
    suite: String,
    meta: Vec<(String, String)>,
    rows: Vec<BenchResult>,
    scalars: Vec<(String, f64)>,
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A float as a JSON number (JSON has no NaN/Inf; those become null).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "null".to_string()
    }
}

impl BenchRecorder {
    /// A recorder for the named suite.
    pub fn new(suite: &str) -> Self {
        Self {
            suite: suite.to_string(),
            meta: Vec::new(),
            rows: Vec::new(),
            scalars: Vec::new(),
        }
    }

    /// Attach a metadata key/value (machine, parameters, git rev, …).
    pub fn meta(&mut self, key: &str, value: impl std::fmt::Display) {
        self.meta.push((key.to_string(), value.to_string()));
    }

    /// Record a bench row.
    pub fn push(&mut self, r: &BenchResult) {
        self.rows.push(r.clone());
    }

    /// Record a free-form scalar (model errors, speedups, curve points).
    pub fn scalar(&mut self, name: &str, value: f64) {
        self.scalars.push((name.to_string(), value));
    }

    /// Serialize everything as a JSON document.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"suite\": \"{}\",\n", json_escape(&self.suite)));
        s.push_str("  \"meta\": {");
        for (i, (k, v)) in self.meta.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    \"{}\": \"{}\"", json_escape(k), json_escape(v)));
        }
        s.push_str("\n  },\n  \"benches\": [");
        for (i, r) in self.rows.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"mean_seconds\": {}, \"ci95_seconds\": {}, \
                 \"samples\": {}, \"throughput_per_second\": {}}}",
                json_escape(&r.name),
                json_num(r.time.mean),
                json_num(r.time.ci95),
                r.time.n,
                r.throughput().map_or("null".to_string(), json_num),
            ));
        }
        s.push_str("\n  ],\n  \"scalars\": {");
        for (i, (k, v)) in self.scalars.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    \"{}\": {}", json_escape(k), json_num(*v)));
        }
        s.push_str("\n  }\n}\n");
        s
    }

    /// Write the JSON document to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_samples() {
        let cfg = BenchConfig { warmup_iters: 1, samples: 5, iters_per_sample: 2 };
        let r = bench("noop", cfg, |_| 1 + 1);
        assert_eq!(r.time.n, 5);
        assert!(r.time.mean >= 0.0);
        assert!(r.throughput().is_none());
    }

    #[test]
    fn throughput_is_elements_over_mean() {
        let cfg = BenchConfig { warmup_iters: 0, samples: 3, iters_per_sample: 1 };
        let r = bench_throughput("tp", cfg, 1000.0, |_| {
            std::thread::sleep(std::time::Duration::from_micros(100));
        });
        let tp = r.throughput().unwrap();
        assert!(tp > 0.0 && tp < 1000.0 / 50e-6, "tp={tp}");
    }

    #[test]
    fn row_contains_name() {
        let cfg = BenchConfig::default();
        let r = bench("my_bench", cfg, |i| i * 2);
        assert!(r.row().contains("my_bench"));
    }

    #[test]
    fn recorder_emits_complete_json() {
        let cfg = BenchConfig { warmup_iters: 0, samples: 2, iters_per_sample: 1 };
        let mut rec = BenchRecorder::new("suite \"x\"");
        rec.meta("p", 16);
        rec.push(&bench("a", cfg, |_| ()));
        rec.push(&bench_throughput("b", cfg, 64.0, |_| ()));
        rec.scalar("rel", 0.03);
        rec.scalar("bad", f64::NAN);
        let json = rec.to_json();
        assert!(json.contains("\"suite \\\"x\\\"\""), "names are escaped");
        assert!(json.contains("\"p\": \"16\""));
        assert!(json.contains("\"name\": \"a\""));
        assert!(json.contains("\"name\": \"b\""));
        assert!(json.contains("\"rel\": 3e-2"));
        assert!(json.contains("\"bad\": null"), "non-finite floats become null");
        // Bench "a" has no throughput denominator.
        assert!(json.contains("\"throughput_per_second\": null"));
    }

    #[test]
    fn recorder_writes_a_file() {
        let mut rec = BenchRecorder::new("filetest");
        rec.scalar("x", 1.0);
        let path = std::env::temp_dir().join("bsps_bench_recorder_test.json");
        let path = path.to_str().unwrap().to_string();
        rec.write(&path).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back, rec.to_json());
        let _ = std::fs::remove_file(&path);
    }
}
