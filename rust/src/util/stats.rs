//! Summary statistics for benchmark samples.

/// Summary of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Sample mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Half-width of a ~95% confidence interval on the mean
    /// (1.96 · stddev / √n; normal approximation).
    pub ci95: f64,
}

/// Compute a [`Summary`] of `xs`. Panics on an empty slice.
#[must_use]
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize: empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let stddev = var.sqrt();
    let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in xs {
        min = min.min(x);
        max = max.max(x);
    }
    Summary { n, mean, stddev, min, max, ci95: 1.96 * stddev / (n as f64).sqrt() }
}

/// Median of a sample (copies + sorts).
#[must_use]
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "median: empty sample");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mid = v.len() / 2;
    if v.len() % 2 == 0 { (v[mid - 1] + v[mid]) / 2.0 } else { v[mid] }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_sample() {
        let s = summarize(&[3.0, 3.0, 3.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.ci95, 0.0);
        assert_eq!((s.min, s.max), (3.0, 3.0));
    }

    #[test]
    fn known_values() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        // sample stddev of 1..4 = sqrt(5/3)
        assert!((s.stddev - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!((s.min, s.max), (1.0, 4.0));
    }

    #[test]
    fn single_sample() {
        let s = summarize(&[7.5]);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        let _ = summarize(&[]);
    }
}
