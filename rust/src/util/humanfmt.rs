//! Human-readable formatting for report output.

/// Format a byte count: `1.5 KB`, `32 MB`, ...
#[must_use]
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format seconds: `1.23 s`, `4.56 ms`, `7.89 µs`, `123 ns`.
#[must_use]
pub fn seconds(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Format a rate in MB/s (the unit Table 1 uses).
#[must_use]
pub fn mbps(bytes_per_sec: f64) -> String {
    format!("{:.1} MB/s", bytes_per_sec / 1e6)
}

/// Format FLOP counts: `2.0 GFLOP`, `1.5 MFLOP`, ...
#[must_use]
pub fn flops(f: f64) -> String {
    if f >= 1e9 {
        format!("{:.2} GFLOP", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2} MFLOP", f / 1e6)
    } else if f >= 1e3 {
        format!("{:.2} kFLOP", f / 1e3)
    } else {
        format!("{f:.0} FLOP")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(1536), "1.5 KB");
        assert_eq!(bytes(32 * 1024 * 1024), "32.0 MB");
    }

    #[test]
    fn seconds_units() {
        assert_eq!(seconds(1.5), "1.500 s");
        assert_eq!(seconds(0.0025), "2.500 ms");
        assert_eq!(seconds(3.2e-6), "3.200 µs");
        assert_eq!(seconds(5e-8), "50 ns");
    }

    #[test]
    fn mbps_matches_table1_style() {
        assert_eq!(mbps(11.0e6), "11.0 MB/s");
    }

    #[test]
    fn flops_units() {
        assert_eq!(flops(136.0), "136 FLOP");
        assert_eq!(flops(2.0e9), "2.00 GFLOP");
    }
}
