//! A minimal `anyhow`-flavoured error type.
//!
//! The offline crate set available to this build has no third-party
//! crates at all, so this module provides the tiny subset of `anyhow`
//! the rest of the crate uses: an opaque [`Error`] holding a message
//! and a context chain, the [`Result`] alias, the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension
//! trait for `Result`/`Option`.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that keeps the blanket `From<E: std::error::Error>`
//! conversion (which is what makes `?` work on `io::Error`,
//! [`crate::stream::StreamError`], …) coherent.
//!
//! ```
//! use bsps::util::error::{anyhow, bail, ensure, Context, Result};
//!
//! fn positive(x: i32) -> Result<i32> {
//!     ensure!(x != 0, "x must not be zero");
//!     if x < 0 {
//!         bail!("x = {x} is negative");
//!     }
//!     Ok(x)
//! }
//!
//! assert_eq!(positive(3).unwrap(), 3);
//! let err = positive(-1).unwrap_err();
//! assert!(err.to_string().contains("negative"));
//! let err = "nan".parse::<i32>().context("parsing the config").unwrap_err();
//! assert!(format!("{err:#}").starts_with("parsing the config: "));
//! ```

use std::fmt;

/// An opaque error: a root message plus outer context layers.
pub struct Error {
    /// Context layers, outermost first; the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    #[must_use]
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { chain: vec![msg.to_string()] }
    }

    /// Wrap the error in one more layer of context.
    #[must_use]
    pub fn context(mut self, ctx: impl fmt::Display) -> Self {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The root cause (the innermost message).
    #[must_use]
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    /// Renders the full context chain, outermost first, `": "`-joined
    /// (matching `anyhow`'s `{:#}` format in both plain and alternate
    /// mode — callers here always want the chain).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` specialized to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an ad-hoc [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an ad-hoc [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an ad-hoc [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

pub use crate::{anyhow, bail, ensure};

/// Render a panic payload (e.g. a poisoned gang's diagnostic) as text.
///
/// The one panic-message renderer shared by the scheduler, the engine,
/// the barrier-watchdog diagnostics and the CLI — `&str` and `String`
/// payloads are returned verbatim, anything else gets a stable marker.
#[must_use]
pub fn panic_payload_msg(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| (*s).to_string()))
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Extension trait adding context to fallible values, like
/// `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context(self, ctx: impl fmt::Display) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::io::Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn context_chain_renders_outermost_first() {
        let e = io_fail().context("loading artifacts").unwrap_err();
        assert_eq!(e.to_string(), "loading artifacts: gone");
        assert_eq!(format!("{e:#}"), "loading artifacts: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<i32, std::io::Error> = Ok(7);
        let v = ok.with_context(|| panic!("must not run")).unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn option_context() {
        let none: Option<i32> = None;
        assert_eq!(none.context("missing value").unwrap_err().to_string(), "missing value");
        assert_eq!(Some(1).context("unused").unwrap(), 1);
    }

    #[test]
    fn panic_payload_renders_strings_and_markers() {
        let s: Box<dyn std::any::Any + Send> = Box::new("static str".to_string());
        assert_eq!(panic_payload_msg(s.as_ref()), "static str");
        let s: Box<dyn std::any::Any + Send> = Box::new("literal");
        assert_eq!(panic_payload_msg(s.as_ref()), "literal");
        let s: Box<dyn std::any::Any + Send> = Box::new(42usize);
        assert_eq!(panic_payload_msg(s.as_ref()), "non-string panic payload");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fell through with {}", x))
        }
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(1).unwrap_err().to_string(), "fell through with 1");
    }
}
