//! Ordinary least-squares line fit.
//!
//! The paper (§5) fits a linear function `time(bytes) = l + g·words`
//! against raw core-to-core write measurements to extract the BSP
//! parameters `g` (slope) and `l` (intercept). [`linear_fit`] is that
//! fit; `model::calibrate` applies it to simulator measurements.

/// Result of a least-squares line fit `y ≈ intercept + slope · x`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LineFit {
    /// Fitted slope.
    pub slope: f64,
    /// Fitted intercept.
    pub intercept: f64,
    /// Coefficient of determination (1 = perfect fit).
    pub r2: f64,
}

/// Least-squares fit of `y ≈ a + b·x`. Panics if fewer than two points
/// or if all `x` are identical.
#[must_use]
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> LineFit {
    assert_eq!(xs.len(), ys.len(), "linear_fit: length mismatch");
    assert!(xs.len() >= 2, "linear_fit: need at least two points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    assert!(sxx > 0.0, "linear_fit: degenerate x values");
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let slope = sxy / sxx;
    let intercept = my - slope * mx;

    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (intercept + slope * x);
            e * e
        })
        .sum();
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    LineFit { slope, intercept, r2 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let f = linear_fit(&xs, &ys);
        assert!((f.slope - 2.0).abs() < 1e-12);
        assert!((f.intercept - 3.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_line_recovers_params() {
        use crate::util::prng::SplitMix64;
        let mut g = SplitMix64::new(11);
        let xs: Vec<f64> = (1..200).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 136.0 + 5.59 * x + (g.next_f64() - 0.5) * 4.0)
            .collect();
        let f = linear_fit(&xs, &ys);
        assert!((f.slope - 5.59).abs() < 0.05, "slope={}", f.slope);
        assert!((f.intercept - 136.0).abs() < 5.0, "intercept={}", f.intercept);
        assert!(f.r2 > 0.999);
    }

    #[test]
    #[should_panic]
    fn degenerate_x_panics() {
        let _ = linear_fit(&[1.0, 1.0], &[2.0, 3.0]);
    }
}
