//! SplitMix64 — a tiny, fast, seedable PRNG (Steele et al., 2014).
//!
//! Used everywhere the crate needs reproducible randomness: workload
//! generation, property tests, shuffles. Not cryptographic.

/// SplitMix64 generator. `Copy` so it can be forked cheaply.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    #[must_use]
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn next_f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.next_f64() as f32) * (hi - lo)
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        // Lemire's multiply-shift; bias negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn next_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Fork an independent generator (for per-core seeds).
    pub fn fork(&mut self) -> Self {
        Self::new(self.next_u64())
    }

    /// A vector of uniform f32s in `[lo, hi)`.
    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.next_f32_in(lo, hi)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = SplitMix64::new(99);
        for _ in 0..10_000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut g = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = g.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues reached");
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut g = SplitMix64::new(3);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| g.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = SplitMix64::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffled");
    }
}
