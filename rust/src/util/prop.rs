//! A miniature property-testing harness (proptest is not in the offline
//! crate set).
//!
//! [`check`] runs a property over `n` SplitMix64-seeded random cases and,
//! on failure, re-runs with progressively "smaller" cases by handing the
//! generator a shrink level (generators are expected to produce smaller
//! structures at higher levels). The failing seed is printed so a case
//! can be replayed deterministically.
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath; the same
//! // example runs for real in this module's unit tests.)
//! use bsps::util::prop::{check, Gen};
//! check("sum is commutative", 100, |g: &mut Gen| {
//!     let a = g.rng.next_below(1000) as i64;
//!     let b = g.rng.next_below(1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::prng::SplitMix64;

/// Case generator handed to properties: a seeded PRNG plus a shrink
/// level (0 = full size; higher = generate smaller structures).
pub struct Gen {
    /// The case generator's PRNG.
    pub rng: SplitMix64,
    /// Current shrink level (0 = full size).
    pub shrink_level: u32,
}

impl Gen {
    /// A size bounded by `max`, scaled down by the shrink level.
    pub fn size(&mut self, max: usize) -> usize {
        let max = max.max(1);
        let scaled = max >> self.shrink_level;
        self.rng.next_range(1, scaled.max(1) + 1)
    }

    /// A vector of f32s with property-scaled length.
    pub fn f32_vec(&mut self, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.size(max_len);
        self.rng.f32_vec(n, lo, hi)
    }
}

/// Run `prop` on `cases` random inputs. Panics (with the failing seed)
/// if any case fails; failing cases are retried at increasing shrink
/// levels to report the smallest reproduction found.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    let base_seed = 0xB5B5_0000u64;
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9));
        let run = |shrink_level: u32| {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut g = Gen { rng: SplitMix64::new(seed), shrink_level };
                prop(&mut g);
            }))
        };
        if run(0).is_err() {
            // Shrink: try smaller structure sizes with the same seed.
            let mut smallest_fail = 0;
            for level in 1..=6 {
                if run(level).is_err() {
                    smallest_fail = level;
                }
            }
            // Re-raise at the most-shrunk failing level for the report.
            let mut g = Gen {
                rng: SplitMix64::new(seed),
                shrink_level: smallest_fail,
            };
            eprintln!(
                "property '{name}' failed: case {case}, seed {seed:#x}, \
                 shrink_level {smallest_fail}"
            );
            prop(&mut g); // panics, surfacing the original assertion
            unreachable!("property failed under catch_unwind but not replay");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("reverse twice is identity", 50, |g| {
            let v = g.f32_vec(64, -10.0, 10.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check("always fails", 5, |_g| {
            panic!("nope");
        });
    }

    #[test]
    fn gen_size_respects_shrink_level() {
        let mut g = Gen { rng: SplitMix64::new(1), shrink_level: 4 };
        for _ in 0..100 {
            assert!(g.size(64) <= 4 + 1);
        }
    }

    #[test]
    fn deterministic_replay() {
        // The same (seed, level) must generate the same data.
        let mut a = Gen { rng: SplitMix64::new(42), shrink_level: 0 };
        let mut b = Gen { rng: SplitMix64::new(42), shrink_level: 0 };
        assert_eq!(a.f32_vec(32, 0.0, 1.0), b.f32_vec(32, 0.0, 1.0));
    }
}
