//! Thread helpers: scoped SPMD launch + a reusable worker pool.
//!
//! (tokio is not in the offline crate set; the BSP runtime needs only
//! fork-join SPMD semantics plus a small pool for background work such
//! as batched PJRT dispatch, so std threads suffice.)

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Run `f(pid)` on `p` scoped threads (one per simulated core) and wait
/// for all of them. Panics from any core are propagated.
pub fn scoped_spmd<F>(p: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    assert!(p > 0, "scoped_spmd: p == 0");
    thread::scope(|s| {
        let handles: Vec<_> = (0..p)
            .map(|pid| {
                let f = &f;
                s.spawn(move || f(pid))
            })
            .collect();
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for h in handles {
            if let Err(e) = h.join() {
                panic.get_or_insert(e);
            }
        }
        if let Some(e) = panic {
            std::panic::resume_unwind(e);
        }
    });
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool executing boxed jobs.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `n` workers.
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "WorkerPool: n == 0");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed: shut down
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker pool channel closed");
    }

    /// Run `f(i)` for `i in 0..n` across the pool and collect results in
    /// order.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.submit(move || {
                let _ = tx.send((i, f(i)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            out[i] = Some(v);
        }
        out.into_iter()
            .map(|v| v.expect("worker died before completing job"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn spmd_runs_every_pid_once() {
        let counts: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        scoped_spmd(8, |pid| {
            counts[pid].fetch_add(1, Ordering::SeqCst);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::SeqCst) == 1));
    }

    #[test]
    #[should_panic(expected = "core 3 died")]
    fn spmd_propagates_panic() {
        scoped_spmd(4, |pid| {
            if pid == 3 {
                panic!("core 3 died");
            }
        });
    }

    #[test]
    fn pool_map_preserves_order() {
        let pool = WorkerPool::new(4);
        let out = pool.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn pool_runs_submitted_jobs() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join workers
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }
}
