//! Thread and buffer pools: scoped SPMD launch, a **persistent gang
//! pool** (the SPMD core threads are spawned once per process and
//! checked out per run, not re-spawned per gang launch), a recycling
//! [`BufferPool`] for token/message payloads, a typed [`TaskPool`]
//! whose submits are plain queue pushes (no per-job boxing) — the
//! substrates behind the engine's zero-allocation steady state — and
//! [`CoreBudget`], the budget-aware checkout/waitlist the multi-gang
//! scheduler admits gangs against instead of letting every gang launch
//! grow the worker pool ad hoc.
//!
//! (tokio is not in the offline crate set; the BSP runtime needs only
//! fork-join SPMD semantics plus small pools for background work, so
//! std threads suffice.)

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Run `f(pid)` on `p` scoped threads (one per simulated core) and wait
/// for all of them. Panics from any core are propagated.
///
/// This spawns (and joins) `p` OS threads per call — the safe,
/// dependency-free reference for fork-join SPMD. The engine itself
/// uses [`GangPool`], which has the same run semantics but keeps the
/// threads alive across runs.
pub fn scoped_spmd<F>(p: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    assert!(p > 0, "scoped_spmd: p == 0");
    thread::scope(|s| {
        let handles: Vec<_> = (0..p)
            .map(|pid| {
                let f = &f;
                s.spawn(move || f(pid))
            })
            .collect();
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for h in handles {
            if let Err(e) = h.join() {
                panic.get_or_insert(e);
            }
        }
        if let Some(e) = panic {
            std::panic::resume_unwind(e);
        }
    });
}

// ------------------------------------------------------------------
// BufferPool

/// A recycling pool of `f32` buffers.
///
/// The engine's steady-state token loop hands every buffer it is done
/// with back here (cleared, capacity kept) and takes warm buffers out
/// instead of allocating: after a couple of warm-up hypersteps the
/// same few allocations circulate forever and the heap is never
/// touched again. [`BufferPool::take`] on an empty pool returns an
/// empty `Vec` (itself allocation-free) whose first fill pays the one
/// warm-up allocation.
pub struct BufferPool {
    bufs: Mutex<Vec<Vec<f32>>>,
    /// Buffers retained beyond this are dropped (bounds pool memory).
    max_retained: usize,
}

impl BufferPool {
    /// A pool retaining at most 64 buffers.
    #[must_use]
    pub fn new() -> Self {
        Self::with_capacity(64)
    }

    /// A pool retaining at most `max_retained` buffers.
    #[must_use]
    pub fn with_capacity(max_retained: usize) -> Self {
        Self { bufs: Mutex::new(Vec::with_capacity(max_retained)), max_retained }
    }

    /// Take a (cleared) buffer out of the pool, or an empty `Vec` if
    /// the pool is dry.
    #[must_use]
    pub fn take(&self) -> Vec<f32> {
        self.bufs
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default()
    }

    /// Return a buffer to the pool. Zero-capacity buffers are not
    /// worth keeping; beyond `max_retained` the buffer is dropped.
    pub fn give(&self, mut buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        let mut bufs = self.bufs.lock().unwrap_or_else(|e| e.into_inner());
        if bufs.len() < self.max_retained {
            bufs.push(buf);
        }
    }

    /// Buffers currently parked in the pool (diagnostics/tests).
    #[must_use]
    pub fn retained(&self) -> usize {
        self.bufs.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

// ------------------------------------------------------------------
// GangPool

type GangJob = Box<dyn FnOnce() + Send + 'static>;

struct GangWorker {
    tx: mpsc::Sender<GangJob>,
}

/// A persistent pool of SPMD gang threads.
///
/// `run(p, f)` runs `f(pid)` for `pid in 0..p` concurrently — pid 0 on
/// the calling thread, pids `1..p` on pooled worker threads that are
/// **checked out for the whole run** (a gang parks on barriers, so its
/// cores must occupy distinct threads; a shared job queue could
/// deadlock two concurrent gangs). Workers are spawned on demand, kept
/// for the life of the process, and reused by later runs: repeated
/// `Gang::run` calls stop paying `p` thread spawns + joins each.
///
/// Panics in any core are caught, the remaining cores are joined (the
/// engine's poisoned barrier unwinds them), and the first panic is
/// re-raised on the caller — the same semantics as [`scoped_spmd`].
///
/// The pool retains at most [`GangPool::set_helper_cap`] idle helper
/// threads between runs. A run always gets the `p - 1` distinct helpers
/// it needs (a gang parks on barriers, so capping the *checkout* would
/// deadlock it); the cap bounds what survives the run, so a scheduler
/// operating under a [`CoreBudget`] keeps the thread count tied to the
/// budget instead of the historical peak. The cap is expressed in the
/// budget's **weighted core units** (see [`CoreClass`]) and rounded up
/// to whole threads, so a mixed-class budget does not over-retain.
pub struct GangPool {
    idle: Mutex<Vec<GangWorker>>,
    /// Idle helpers retained beyond this are dropped at give-back.
    helper_cap: AtomicUsize,
}

impl GangPool {
    /// An empty pool (no threads until the first `run`).
    #[must_use]
    pub const fn new() -> Self {
        Self { idle: Mutex::new(Vec::new()), helper_cap: AtomicUsize::new(usize::MAX) }
    }

    /// The process-wide pool used by the engine.
    #[must_use]
    pub fn global() -> &'static GangPool {
        static POOL: GangPool = GangPool::new();
        &POOL
    }

    fn spawn_worker() -> GangWorker {
        let (tx, rx) = mpsc::channel::<GangJob>();
        thread::Builder::new()
            .name("bsps-gang".into())
            .spawn(move || {
                // Jobs are fully wrapped in catch_unwind by `run`, so
                // this loop — and the thread — cannot die early.
                while let Ok(job) = rx.recv() {
                    job();
                }
            })
            .expect("spawn gang worker");
        GangWorker { tx }
    }

    /// Worker threads currently parked in the pool (diagnostics/tests).
    #[must_use]
    pub fn idle_workers(&self) -> usize {
        self.idle.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Bound the idle helper threads retained between runs, in
    /// **weighted core units** (rounded up to whole threads, clamped to
    /// at least 1). Surplus parked workers are dropped immediately —
    /// each one's job channel closes and its thread exits. Runs that
    /// need more helpers than the cap still get them (correctness
    /// requires `p - 1` distinct threads); the surplus is shed when the
    /// gang retires. The multi-gang scheduler sets this from its
    /// [`CoreBudget`]'s weighted capacity clamped to its physical core
    /// count, so the persistent pool never outgrows the budget it
    /// serves — and a mixed-class budget whose weighted capacity dwarfs
    /// its thread demand does not over-retain.
    pub fn set_helper_cap(&self, cap: f64) {
        let cap = (cap.ceil().max(1.0)) as usize;
        self.helper_cap.store(cap, Ordering::Relaxed);
        self.idle.lock().unwrap_or_else(|e| e.into_inner()).truncate(cap);
    }

    /// The current idle-helper retention cap.
    #[must_use]
    pub fn helper_cap(&self) -> usize {
        self.helper_cap.load(Ordering::Relaxed)
    }

    /// Run `f(pid)` for `pid in 0..p` concurrently and wait for all of
    /// them; the first panicking core's payload is re-raised.
    pub fn run<F>(&self, p: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        assert!(p > 0, "GangPool::run: p == 0");
        if p == 1 {
            f(0);
            return;
        }
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the borrow of `f` is erased to 'static so it can ride
        // into the persistent workers' job boxes. Every dispatched job
        // is joined below (one completion message per job, sent *after*
        // the job's catch_unwind returns) before this function returns
        // or unwinds, so no job can touch `f` after it is gone.
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { &*(f_ref as *const (dyn Fn(usize) + Sync)) };

        let helpers = p - 1;
        let mut workers = {
            let mut idle = self.idle.lock().unwrap_or_else(|e| e.into_inner());
            let keep = idle.len() - idle.len().min(helpers);
            idle.split_off(keep)
        };
        while workers.len() < helpers {
            workers.push(Self::spawn_worker());
        }

        let (done_tx, done_rx) = mpsc::channel::<thread::Result<()>>();
        let mut dispatched = 0usize;
        for (i, w) in workers.iter().enumerate() {
            let pid = i + 1;
            let tx = done_tx.clone();
            let job: GangJob = Box::new(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f_static(pid)));
                let _ = tx.send(r);
            });
            if w.tx.send(job).is_ok() {
                dispatched += 1;
            }
        }
        drop(done_tx);

        // pid 0 runs on the caller's thread.
        let mut first_panic = catch_unwind(AssertUnwindSafe(|| f(0))).err();
        for _ in 0..dispatched {
            match done_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_panic.get_or_insert(e);
                }
                // All senders gone: every job has finished or been
                // dropped unrun; either way `f` is no longer referenced.
                Err(_) => break,
            }
        }
        {
            let mut idle = self.idle.lock().unwrap_or_else(|e| e.into_inner());
            idle.append(&mut workers);
            idle.truncate(self.helper_cap.load(Ordering::Relaxed));
        }
        assert!(
            dispatched == helpers || first_panic.is_some(),
            "gang worker unavailable"
        );
        if let Some(e) = first_panic {
            resume_unwind(e);
        }
    }
}

impl Default for GangPool {
    fn default() -> Self {
        Self::new()
    }
}

// ------------------------------------------------------------------
// CoreBudget

/// A class of cores in a [`CoreBudget`]: a machine profile's cores,
/// counted at a capacity `weight` relative to the budget's reference
/// class (weight 1.0). A "fast" core (higher per-core BSPS throughput
/// at the reference arithmetic intensity) counts for more than a
/// "slow" one, so weighted occupancy over a mixed Epiphany/Phi-class
/// budget measures delivered capacity, not thread-count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreClass {
    /// Machine-profile name this class admits (`AcceleratorParams::name`).
    pub name: &'static str,
    /// Capacity weight of one core of this class (reference = 1.0).
    pub weight: f64,
}

impl CoreClass {
    /// The single uniform class behind [`CoreBudget::new`]: every core
    /// weighs 1.0 — the weighted budget degrades to the old counting
    /// budget.
    #[must_use]
    pub fn uniform() -> Self {
        Self { name: "core", weight: 1.0 }
    }

    /// Derive a class for `machine` with its weight set to the ratio of
    /// per-core BSPS throughputs (`model::hetero::unit_throughput / p`)
    /// against `reference` at the given arithmetic `intensity` — the
    /// same `min(compute, fetch)` rate `model::hetero::optimal_split`
    /// splits work by, so admission and work-splitting price cores
    /// consistently.
    #[must_use]
    pub fn for_machine(
        machine: &crate::model::params::AcceleratorParams,
        reference: &crate::model::params::AcceleratorParams,
        intensity: f64,
    ) -> Self {
        let per_core = |m: &crate::model::params::AcceleratorParams| {
            crate::model::hetero::unit_throughput(m, intensity) / m.p as f64
        };
        Self { name: machine.name, weight: per_core(machine) / per_core(reference) }
    }
}

/// Ticketed waitlist state behind a [`CoreBudget`].
struct BudgetState {
    /// Free cores per class.
    class_available: Vec<usize>,
    /// Next ticket to hand out to an [`CoreBudget::acquire`] caller.
    next_ticket: u64,
    /// Ticket currently first in line.
    serving: u64,
}

/// A global budget of simulated cores that concurrent gangs check
/// worker capacity out of.
///
/// [`GangPool`] hands each run disjoint threads, but nothing bounds how
/// many it spawns: ten concurrent 16-core gangs happily occupy 160
/// threads. A `CoreBudget` makes the capacity an explicit, shared
/// resource: a gang **checks out** its `p` cores before running
/// (blocking on a FIFO waitlist via [`CoreBudget::acquire`], or
/// politely declining via [`CoreBudget::try_acquire`] — the
/// backfill path the multi-gang scheduler uses) and the RAII
/// [`BudgetLease`] returns them when the gang retires.
///
/// A budget holds one or more [`CoreClass`]es ([`CoreBudget::new`] is
/// the single-class fast path; [`CoreBudget::with_classes`] models a
/// heterogeneous host, e.g. 16 Epiphany cores next to 61 Phi-class
/// cores). Admission is exact integer accounting **per class** — a gang
/// needs `p` cores of *its* machine's class — while `weighted_*`
/// accessors report capacity/usage in weighted units for occupancy.
///
/// Fairness: `acquire` is strictly FIFO (tickets) across all classes —
/// a large gang at the head of the line blocks later arrivals even
/// while enough cores for *them* are free (including cores of a class
/// the head does not even want). `try_acquire` deliberately bypasses
/// the waitlist so a scheduler can backfill those holes; a steady
/// stream of backfilled small gangs can therefore starve a parked large
/// `acquire` (see `docs/ARCHITECTURE.md`, "Multi-gang scheduling").
pub struct CoreBudget {
    classes: Vec<CoreClass>,
    /// Physical cores per class.
    class_capacity: Vec<usize>,
    /// Total physical cores (Σ class capacities).
    capacity: usize,
    state: Mutex<BudgetState>,
    cv: Condvar,
}

/// RAII checkout of cores from a [`CoreBudget`]; returns them on drop.
pub struct BudgetLease<'a> {
    budget: &'a CoreBudget,
    class: usize,
    cores: usize,
}

impl BudgetLease<'_> {
    /// Cores held by this lease.
    #[must_use]
    pub fn cores(&self) -> usize {
        self.cores
    }

    /// The class the cores were checked out of.
    #[must_use]
    pub fn class(&self) -> usize {
        self.class
    }

    /// The lease's capacity in weighted units (`cores × class weight`).
    #[must_use]
    pub fn weighted(&self) -> f64 {
        self.cores as f64 * self.budget.classes[self.class].weight
    }
}

impl Drop for BudgetLease<'_> {
    fn drop(&mut self) {
        let mut st = self.budget.state.lock().unwrap_or_else(|e| e.into_inner());
        st.class_available[self.class] += self.cores;
        debug_assert!(
            st.class_available[self.class] <= self.budget.class_capacity[self.class]
        );
        // Wake everyone: the FIFO head may now fit, and try_acquire
        // callers parked in acquire-tickets behind it re-check too.
        self.budget.cv.notify_all();
    }
}

impl CoreBudget {
    /// A budget of `capacity` cores in one uniform class (weight 1.0) —
    /// the single-class fast path; all the weighted accessors degrade
    /// to plain core counts.
    #[must_use]
    pub fn new(capacity: usize) -> Self {
        Self::with_classes(vec![(CoreClass::uniform(), capacity)])
    }

    /// A budget with one pool of cores per [`CoreClass`]. Class names
    /// must be distinct (jobs are matched to classes by machine name),
    /// every capacity positive, and every weight positive and finite.
    #[must_use]
    pub fn with_classes(classes: Vec<(CoreClass, usize)>) -> Self {
        assert!(!classes.is_empty(), "CoreBudget: no classes");
        let mut capacity = 0usize;
        for (i, (class, cap)) in classes.iter().enumerate() {
            assert!(*cap > 0, "CoreBudget: class {:?} capacity == 0", class.name);
            assert!(
                class.weight.is_finite() && class.weight > 0.0,
                "CoreBudget: class {:?} weight {} must be positive and finite",
                class.name,
                class.weight
            );
            assert!(
                classes[..i].iter().all(|(c, _)| c.name != class.name),
                "CoreBudget: duplicate class name {:?}",
                class.name
            );
            capacity += cap;
        }
        let class_available: Vec<usize> = classes.iter().map(|(_, cap)| *cap).collect();
        let (classes, class_capacity): (Vec<_>, Vec<_>) = classes.into_iter().unzip();
        Self {
            classes,
            class_capacity,
            capacity,
            state: Mutex::new(BudgetState {
                class_available,
                next_ticket: 0,
                serving: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// A budget sized to the host's parallelism (the `--cores` default).
    #[must_use]
    pub fn host() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    /// Total physical cores across all classes.
    #[must_use]
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of core classes (1 for [`CoreBudget::new`] budgets).
    #[must_use]
    pub fn class_count(&self) -> usize {
        self.classes.len()
    }

    /// The class table entry at `idx`.
    #[must_use]
    pub fn class(&self, idx: usize) -> &CoreClass {
        &self.classes[idx]
    }

    /// Physical cores in class `idx`.
    #[must_use]
    pub fn class_capacity(&self, idx: usize) -> usize {
        self.class_capacity[idx]
    }

    /// The class admitting machines named `name`, if any. Single-class
    /// budgets admit every machine through class 0 (callers fall back
    /// to 0 on `None` — the pre-heterogeneity behavior).
    #[must_use]
    pub fn class_for(&self, name: &str) -> Option<usize> {
        self.classes.iter().position(|c| c.name == name)
    }

    /// Total capacity in weighted units (`Σ cores × weight`). Equals
    /// [`CoreBudget::capacity`] for single-class budgets.
    #[must_use]
    pub fn weighted_capacity(&self) -> f64 {
        self.classes
            .iter()
            .zip(&self.class_capacity)
            .map(|(c, cap)| c.weight * *cap as f64)
            .sum()
    }

    /// Physical cores currently checked out (all classes).
    #[must_use]
    pub fn in_use(&self) -> usize {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        self.capacity - st.class_available.iter().sum::<usize>()
    }

    /// Physical cores currently free (all classes; ignores the waitlist).
    #[must_use]
    pub fn available(&self) -> usize {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.class_available.iter().sum()
    }

    /// Checked-out capacity in weighted units.
    #[must_use]
    pub fn weighted_in_use(&self) -> f64 {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        self.classes
            .iter()
            .zip(&self.class_capacity)
            .zip(&st.class_available)
            .map(|((c, cap), avail)| c.weight * (*cap - *avail) as f64)
            .sum()
    }

    /// Cores of class `idx` currently checked out.
    #[must_use]
    pub fn class_in_use(&self, idx: usize) -> usize {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        self.class_capacity[idx] - st.class_available[idx]
    }

    /// Per-class cores currently checked out, in class order.
    #[must_use]
    pub fn class_usage(&self) -> Vec<usize> {
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        self.class_capacity
            .iter()
            .zip(&st.class_available)
            .map(|(cap, avail)| cap - avail)
            .collect()
    }

    fn check_request(&self, what: &str, class: usize, cores: usize) {
        assert!(class < self.classes.len(), "{what}: class {class} out of range");
        assert!(cores > 0, "{what}: cores == 0");
        assert!(
            cores <= self.class_capacity[class],
            "{what}: {cores} cores exceed the budget capacity {} (class {})",
            self.class_capacity[class],
            self.classes[class].name
        );
    }

    /// Check `cores` out of class 0 immediately if they are free,
    /// without joining the waitlist — the scheduler's **backfill** path
    /// on single-class budgets. Returns `None` when the budget cannot
    /// satisfy the request right now.
    ///
    /// Panics if `cores` exceeds the class capacity (such a request
    /// could never succeed — callers must reject it, not spin on it).
    pub fn try_acquire(&self, cores: usize) -> Option<BudgetLease<'_>> {
        self.try_acquire_class(0, cores)
    }

    /// Per-class [`CoreBudget::try_acquire`]: backfill `cores` out of
    /// class `class` if they are free right now.
    pub fn try_acquire_class(&self, class: usize, cores: usize) -> Option<BudgetLease<'_>> {
        self.check_request("try_acquire", class, cores);
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if st.class_available[class] >= cores {
            st.class_available[class] -= cores;
            Some(BudgetLease { budget: self, class, cores })
        } else {
            None
        }
    }

    /// Check `cores` out of class 0, blocking on a strictly FIFO
    /// waitlist until they are free. This is the scheduler-mediated
    /// entry point's checkout (`bsp::engine::Gang::with_budget`).
    ///
    /// Panics if `cores` exceeds the class capacity (waiting would
    /// deadlock: the request can never be satisfied).
    #[must_use]
    pub fn acquire(&self, cores: usize) -> BudgetLease<'_> {
        self.acquire_class(0, cores)
    }

    /// Per-class [`CoreBudget::acquire`]: the FIFO waitlist is shared
    /// across classes, so a parked head blocks later tickets even for
    /// other classes (backfill via [`CoreBudget::try_acquire_class`]
    /// routes around that, same as the single-class story).
    #[must_use]
    pub fn acquire_class(&self, class: usize, cores: usize) -> BudgetLease<'_> {
        self.check_request("acquire", class, cores);
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        loop {
            if st.serving == ticket && st.class_available[class] >= cores {
                st.class_available[class] -= cores;
                st.serving += 1;
                // The next ticket in line may also fit what remains.
                self.cv.notify_all();
                return BudgetLease { budget: self, class, cores };
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }
}

// ------------------------------------------------------------------
// TaskPool

struct TaskQueue<T> {
    q: Mutex<VecDeque<T>>,
    cv: Condvar,
}

/// A persistent pool of workers draining a **typed** job queue through
/// one fixed handler.
///
/// Unlike a boxed-closure job pool, submitting does not allocate: it
/// pushes a plain value onto a pre-reserved `VecDeque`, so a
/// steady-state submitter performs **zero heap allocations** per job.
/// The engine uses one process-wide `TaskPool` for stream token fills.
///
/// Workers live for the life of the pool's queue (they hold their own
/// `Arc`s); the pool is intended to be stored in a `static` and never
/// dropped. A panicking handler is caught and the worker keeps going.
pub struct TaskPool<T: Send + 'static> {
    shared: Arc<TaskQueue<T>>,
}

impl<T: Send + 'static> TaskPool<T> {
    /// Spawn `workers` threads, each running `handler` on every item it
    /// pops off the queue.
    #[must_use]
    pub fn new<H>(workers: usize, handler: H) -> Self
    where
        H: Fn(T) + Send + Sync + 'static,
    {
        assert!(workers > 0, "TaskPool: workers == 0");
        let shared = Arc::new(TaskQueue {
            q: Mutex::new(VecDeque::with_capacity(256)),
            cv: Condvar::new(),
        });
        let handler = Arc::new(handler);
        for _ in 0..workers {
            let shared = Arc::clone(&shared);
            let handler = Arc::clone(&handler);
            thread::Builder::new()
                .name("bsps-task".into())
                .spawn(move || loop {
                    let item = {
                        let mut q = shared.q.lock().unwrap_or_else(|e| e.into_inner());
                        loop {
                            if let Some(item) = q.pop_front() {
                                break item;
                            }
                            q = shared.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                        }
                    };
                    let _ = catch_unwind(AssertUnwindSafe(|| handler(item)));
                })
                .expect("spawn task worker");
        }
        Self { shared }
    }

    /// Queue an item for the workers (a `VecDeque` push — no boxing).
    pub fn submit(&self, item: T) {
        self.shared
            .q
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push_back(item);
        self.shared.cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn spmd_runs_every_pid_once() {
        let counts: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        scoped_spmd(8, |pid| {
            counts[pid].fetch_add(1, Ordering::SeqCst);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::SeqCst) == 1));
    }

    #[test]
    #[should_panic(expected = "core 3 died")]
    fn spmd_propagates_panic() {
        scoped_spmd(4, |pid| {
            if pid == 3 {
                panic!("core 3 died");
            }
        });
    }

    #[test]
    fn buffer_pool_recycles_capacity() {
        let pool = BufferPool::new();
        let mut b = pool.take();
        assert_eq!(b.capacity(), 0, "dry pool hands out empty vecs");
        b.extend_from_slice(&[1.0; 64]);
        let ptr = b.as_ptr();
        pool.give(b);
        assert_eq!(pool.retained(), 1);
        let b2 = pool.take();
        assert_eq!(b2.as_ptr(), ptr, "same allocation comes back");
        assert!(b2.is_empty() && b2.capacity() >= 64);
        assert_eq!(pool.retained(), 0);
    }

    #[test]
    fn buffer_pool_bounds_retention() {
        let pool = BufferPool::with_capacity(2);
        for _ in 0..5 {
            pool.give(vec![0.0; 8]);
        }
        assert_eq!(pool.retained(), 2);
        pool.give(Vec::new()); // zero-capacity: not retained
        assert_eq!(pool.retained(), 2);
    }

    #[test]
    fn gang_pool_runs_every_pid_and_reuses_workers() {
        let pool = GangPool::new();
        let counts: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        pool.run(8, |pid| {
            counts[pid].fetch_add(1, Ordering::SeqCst);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::SeqCst) == 1));
        // 7 helpers spawned (pid 0 ran inline); all parked again.
        assert_eq!(pool.idle_workers(), 7);
        // A second run must not grow the pool.
        pool.run(8, |_| {});
        assert_eq!(pool.idle_workers(), 7);
        // A smaller gang uses a subset.
        pool.run(3, |_| {});
        assert_eq!(pool.idle_workers(), 7);
    }

    #[test]
    fn gang_pool_propagates_panic_and_survives() {
        let pool = GangPool::new();
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, |pid| {
                if pid == 2 {
                    panic!("core 2 died");
                }
            });
        }));
        assert!(r.is_err());
        // Workers returned to the pool and still usable.
        assert_eq!(pool.idle_workers(), 3);
        let ran = AtomicUsize::new(0);
        pool.run(4, |_| {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn gang_pool_concurrent_gangs_get_disjoint_workers() {
        // Two gangs of 4 through one pool at once: checkout semantics
        // must give each gang its own threads (no deadlock), and the
        // pool ends with at most the peak demand.
        static POOL: GangPool = GangPool::new();
        let total = Arc::new(AtomicUsize::new(0));
        thread::scope(|s| {
            for _ in 0..2 {
                let total = Arc::clone(&total);
                s.spawn(move || {
                    POOL.run(4, |_| {
                        total.fetch_add(1, Ordering::SeqCst);
                        // Hold the worker long enough that the gangs
                        // genuinely overlap.
                        thread::sleep(std::time::Duration::from_millis(10));
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 8);
        assert!(POOL.idle_workers() <= 6, "at most 2×3 helpers spawned");
    }

    #[test]
    fn gang_pool_helper_cap_bounds_retained_workers() {
        let pool = GangPool::new();
        assert_eq!(pool.helper_cap(), usize::MAX, "uncapped by default");
        pool.run(8, |_| {});
        assert_eq!(pool.idle_workers(), 7);
        // Capping sheds surplus parked helpers immediately.
        pool.set_helper_cap(3.0);
        assert_eq!(pool.helper_cap(), 3);
        assert_eq!(pool.idle_workers(), 3);
        // A bigger gang still gets all the helpers it needs, but only
        // the cap survives the run.
        let ran = AtomicUsize::new(0);
        pool.run(8, |_| {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 8);
        assert_eq!(pool.idle_workers(), 3);
        // Fractional weighted caps round up to whole threads.
        pool.set_helper_cap(1.2);
        assert_eq!(pool.helper_cap(), 2);
        assert_eq!(pool.idle_workers(), 2);
        // The clamp keeps at least one helper.
        pool.set_helper_cap(0.0);
        assert_eq!(pool.helper_cap(), 1);
        assert_eq!(pool.idle_workers(), 1);
    }

    #[test]
    fn core_budget_counts_checkouts_and_returns_on_drop() {
        let b = CoreBudget::new(8);
        assert_eq!(b.capacity(), 8);
        assert_eq!(b.available(), 8);
        let l1 = b.try_acquire(5).expect("5 of 8 fit");
        assert_eq!(l1.cores(), 5);
        assert_eq!(b.available(), 3);
        assert_eq!(b.in_use(), 5);
        assert!(b.try_acquire(4).is_none(), "only 3 left");
        let l2 = b.try_acquire(3).expect("exact fit");
        assert_eq!(b.available(), 0);
        drop(l1);
        assert_eq!(b.available(), 5);
        drop(l2);
        assert_eq!(b.available(), 8);
    }

    #[test]
    #[should_panic(expected = "exceed the budget capacity")]
    fn core_budget_rejects_impossible_requests() {
        let b = CoreBudget::new(4);
        let _ = b.try_acquire(5);
    }

    #[test]
    fn core_budget_acquire_blocks_until_cores_free() {
        let b = Arc::new(CoreBudget::new(4));
        let lease = b.try_acquire(3).unwrap();
        let b2 = Arc::clone(&b);
        let t = thread::spawn(move || {
            // Needs 2, only 1 free: must block until the main thread
            // releases, then run.
            let _l = b2.acquire(2);
            b2.in_use()
        });
        thread::sleep(std::time::Duration::from_millis(50));
        drop(lease);
        assert_eq!(t.join().unwrap(), 2);
        assert_eq!(b.available(), 4);
    }

    #[test]
    fn core_budget_acquire_is_fifo() {
        // Three waiters of descending size behind a full budget: FIFO
        // tickets mean they are served strictly in arrival order even
        // though the later (smaller) ones would fit earlier holes.
        let b = Arc::new(CoreBudget::new(4));
        let order = Arc::new(Mutex::new(Vec::new()));
        let gate = b.try_acquire(4).unwrap();
        let mut handles = Vec::new();
        for (i, cores) in [(0usize, 4usize), (1, 2), (2, 1)] {
            let b = Arc::clone(&b);
            let order = Arc::clone(&order);
            handles.push(thread::spawn(move || {
                let _l = b.acquire(cores);
                order.lock().unwrap().push(i);
                // Hold briefly so overlap is possible but order is set
                // by the acquire itself.
                thread::sleep(std::time::Duration::from_millis(5));
            }));
            // Let each waiter park before the next takes its ticket.
            thread::sleep(std::time::Duration::from_millis(30));
        }
        drop(gate);
        for h in handles {
            h.join().unwrap();
        }
        let got = order.lock().unwrap().clone();
        assert_eq!(got[0], 0, "the head ticket (largest gang) goes first");
        assert_eq!(b.available(), 4);
    }

    #[test]
    fn core_budget_try_acquire_backfills_past_a_parked_head() {
        // A large acquire() parks at the head of the line; a small
        // try_acquire must still succeed (backfill semantics).
        let b = Arc::new(CoreBudget::new(4));
        let held = b.try_acquire(2).unwrap();
        let b2 = Arc::clone(&b);
        let big = thread::spawn(move || {
            let _l = b2.acquire(4); // cannot fit until everything frees
        });
        thread::sleep(std::time::Duration::from_millis(50));
        let small = b.try_acquire(1).expect("backfill past the parked head");
        drop(small);
        drop(held);
        big.join().unwrap();
        assert_eq!(b.available(), 4);
    }

    fn two_class_budget() -> CoreBudget {
        CoreBudget::with_classes(vec![
            (CoreClass { name: "slow", weight: 1.0 }, 4),
            (CoreClass { name: "fast", weight: 10.0 }, 2),
        ])
    }

    #[test]
    fn weighted_budget_accounts_per_class() {
        let b = two_class_budget();
        assert_eq!(b.capacity(), 6, "physical cores sum over classes");
        assert!((b.weighted_capacity() - 24.0).abs() < 1e-12);
        assert_eq!(b.class_for("fast"), Some(1));
        assert_eq!(b.class_for("epiphany3"), None);

        let slow = b.try_acquire_class(0, 3).expect("3 of 4 slow cores");
        let fast = b.try_acquire_class(1, 1).expect("1 of 2 fast cores");
        assert_eq!(b.in_use(), 4);
        assert!((b.weighted_in_use() - 13.0).abs() < 1e-12, "3·1 + 1·10");
        assert!((slow.weighted() - 3.0).abs() < 1e-12);
        assert!((fast.weighted() - 10.0).abs() < 1e-12);
        assert_eq!(b.class_usage(), vec![3, 1]);

        // Classes are disjoint pools: the slow class being nearly full
        // does not block the fast class, and vice versa.
        assert!(b.try_acquire_class(0, 2).is_none(), "only 1 slow core left");
        let fast2 = b.try_acquire_class(1, 1).expect("fast class still has room");
        assert_eq!(b.class_in_use(1), 2);
        drop(fast2);
        drop(fast);
        drop(slow);
        assert_eq!(b.available(), 6);
        assert!((b.weighted_in_use()).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "exceed the budget capacity")]
    fn weighted_budget_rejects_impossible_class_requests() {
        let b = two_class_budget();
        // 3 fast cores can never exist (class capacity 2) even though 3
        // physical cores are a fraction of the total.
        let _ = b.try_acquire_class(1, 3);
    }

    #[test]
    fn weighted_budget_fifo_spans_classes_and_backfill_routes_around() {
        // A parked head waiting on fast cores blocks a later slow-class
        // acquire (one FIFO line for the whole budget), but
        // try_acquire_class backfills the idle slow cores.
        let b = Arc::new(two_class_budget());
        let gate = b.try_acquire_class(1, 2).unwrap();
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for (i, (class, cores)) in [(0usize, (1usize, 1usize)), (1, (0, 1))] {
            let b = Arc::clone(&b);
            let order = Arc::clone(&order);
            handles.push(thread::spawn(move || {
                let _l = b.acquire_class(class, cores);
                order.lock().unwrap().push(i);
                thread::sleep(std::time::Duration::from_millis(5));
            }));
            thread::sleep(std::time::Duration::from_millis(30));
        }
        // Backfill: slow cores are all free and the waitlist is parked.
        let fill = b.try_acquire_class(0, 4).expect("backfill past the parked head");
        drop(fill);
        drop(gate);
        for h in handles {
            h.join().unwrap();
        }
        let got = order.lock().unwrap().clone();
        assert_eq!(got, vec![0, 1], "strict ticket order across classes");
        assert_eq!(b.available(), 6);
    }

    #[test]
    fn single_class_budget_degrades_to_the_counting_budget() {
        // CoreBudget::new(n) must behave exactly like the pre-weighted
        // budget: one class, weight 1.0, weighted == unweighted.
        let b = CoreBudget::new(8);
        assert_eq!(b.class_count(), 1);
        assert_eq!(b.class(0).weight.to_bits(), 1.0f64.to_bits());
        assert_eq!(b.capacity(), 8);
        assert_eq!(b.weighted_capacity().to_bits(), 8.0f64.to_bits());
        let l = b.acquire(5);
        assert_eq!(b.in_use(), 5);
        assert_eq!(b.weighted_in_use().to_bits(), 5.0f64.to_bits());
        assert_eq!(l.class(), 0);
        assert_eq!(l.weighted().to_bits(), 5.0f64.to_bits());
        drop(l);
        assert_eq!(b.weighted_in_use().to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn core_class_for_machine_weights_by_throughput_ratio() {
        use crate::model::params::AcceleratorParams;
        let epi = AcceleratorParams::epiphany3();
        let phi = AcceleratorParams::xeonphi_like();
        // Against itself the weight is exactly 1.
        let own = CoreClass::for_machine(&epi, &epi, 8.0);
        assert_eq!(own.name, "epiphany3");
        assert!((own.weight - 1.0).abs() < 1e-12);
        // At I = 8 the Epiphany is fetch-bound (e = 43.4 > 8): per-core
        // rate I·r/e; the Phi is compute-bound (e = 0.8 < 8): rate r.
        let w = CoreClass::for_machine(&phi, &epi, 8.0).weight;
        let expect = phi.r / (8.0 * epi.r / epi.e);
        assert!((w - expect).abs() / expect < 1e-12, "{w} vs {expect}");
        assert!(w > 100.0, "a Phi-class core dwarfs an Epiphany core");
        // Intensity moves the ratio: compute-bound on both sides at
        // high I the ratio is just r/r.
        let w_hi = CoreClass::for_machine(&phi, &epi, 1e6).weight;
        assert!((w_hi - phi.r / epi.r).abs() / w_hi < 1e-9);
    }

    #[test]
    fn task_pool_handles_items_without_boxing() {
        let handled = Arc::new(AtomicUsize::new(0));
        let h2 = Arc::clone(&handled);
        let pool: TaskPool<usize> = TaskPool::new(2, move |n| {
            h2.fetch_add(n, Ordering::SeqCst);
        });
        for _ in 0..100 {
            pool.submit(1);
        }
        // Drain: the queue is emptied by the workers.
        while handled.load(Ordering::SeqCst) < 100 {
            thread::yield_now();
        }
        assert_eq!(handled.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn task_pool_survives_panicking_handler() {
        let handled = Arc::new(AtomicUsize::new(0));
        let h2 = Arc::clone(&handled);
        let pool: TaskPool<bool> = TaskPool::new(1, move |explode| {
            if explode {
                panic!("handler died");
            }
            h2.fetch_add(1, Ordering::SeqCst);
        });
        pool.submit(true);
        pool.submit(false);
        while handled.load(Ordering::SeqCst) < 1 {
            thread::yield_now();
        }
        assert_eq!(handled.load(Ordering::SeqCst), 1);
    }
}
