"""L1 Pallas kernel for ELLPACK SpMV — the §7 sparse extension.

The paper's future work mentions "preliminary work on sparse matrix
vector multiplication ... within the BSPS model". We realize the
per-hyperstep compute as an ELLPACK-format SpMV: each core holds a token
of ``rows`` matrix rows (values + column indices, padded to a fixed
``nnz_per_row``) plus the dense input vector block, and produces the
corresponding slice of y.

ELLPACK is the natural sparse token format for a scratchpad machine: it
is rectangular (so a token has a static size, as Definition 1 requires)
and its gather is regular.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_ell_kernel(values_ref, cols_ref, x_ref, o_ref):
    values = values_ref[...]
    cols = cols_ref[...]
    x = x_ref[...]
    n = x.shape[0]
    gathered = x[jnp.clip(cols, 0, n - 1)]
    mask = (cols >= 0).astype(values.dtype)
    o_ref[...] = jnp.sum(values * gathered * mask, axis=1)


def spmv_ell(values, cols, x):
    """ELLPACK SpMV token compute: y[i] = Σ_j values[i,j] · x[cols[i,j]].

    ``cols`` entries of -1 are padding and contribute zero. The whole
    token (values, cols, x) is resident — the rust coordinator streams
    row-block tokens and the matching x window per hyperstep.
    """
    rows, nnz = values.shape
    assert cols.shape == (rows, nnz)
    return pl.pallas_call(
        _spmv_ell_kernel,
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(values, cols, x)
