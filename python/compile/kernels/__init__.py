"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from . import axpy, inner_product, matmul_block, ref, spmv  # noqa: F401
