"""L1 Pallas kernel for AXPY — the §7 video-pipeline per-frame compute.

The paper's future-work section imagines real-time video processing where
each hyperstep analyses one frame and the hypersteps must stay
*bandwidth heavy* so the feed is processed in real time. Our video
pipeline example (rust/src/algos/video.rs) charges its per-frame compute
as a small constant-work filter; this kernel is the PJRT-executed
realization of that filter: ``y + alpha * x`` over a frame-sized vector,
streamed through VMEM in token-sized blocks.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = y_ref[...] + alpha_ref[0] * x_ref[...]


def axpy(alpha, x, y, *, token: int | None = None):
    """Return ``y + alpha * x`` (f32), optionally streamed in tokens.

    ``alpha`` is passed as a (1,) f32 array so the whole computation has
    array inputs (scalars complicate the PJRT literal marshaling on the
    rust side for no benefit).
    """
    (n,) = x.shape
    assert y.shape == (n,)
    if token is None:
        token = n
    assert n % token == 0
    m = n // token
    return pl.pallas_call(
        _axpy_kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((token,), lambda i: (i,)),
            pl.BlockSpec((token,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((token,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(alpha, x, y)
