"""L1 Pallas kernels for the streaming inner product (paper §3.1).

``inprod_partial`` is Algorithm 1's per-hyperstep body: the two resident
tokens (subvectors of C components each) are multiplied element-wise and
reduced, and the result is added to the running partial sum alpha_s held
by the core.

``streamed_inprod`` collapses the whole token loop of Algorithm 1 into a
single Pallas grid: the grid axis is the hyperstep index, the BlockSpec
carves the per-core stream Σ_s into C-sized tokens, and the scalar
accumulator is carried in the resident (1, 1) output block — the same
structural trick as the paper's partial-sum register.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _inprod_partial_kernel(acc_ref, u_ref, v_ref, o_ref):
    o_ref[...] = acc_ref[...] + jnp.dot(
        u_ref[...], v_ref[...], preferred_element_type=jnp.float32
    )


def inprod_partial(acc, u, v):
    """One hyperstep of Algorithm 1: return ``acc + <u, v>``.

    ``acc`` is a scalar f32 (shape ()); ``u``/``v`` are the two resident
    tokens of C f32 components.
    """
    (c,) = u.shape
    assert v.shape == (c,)
    return pl.pallas_call(
        _inprod_partial_kernel,
        out_shape=jax.ShapeDtypeStruct((), jnp.float32),
        interpret=True,
    )(acc, u, v)


def _streamed_inprod_kernel(u_ref, v_ref, o_ref, *, num_tokens):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        u_ref[...], v_ref[...], preferred_element_type=jnp.float32
    )


def streamed_inprod(u, v, *, token: int = 64):
    """Full Algorithm 1 token loop for one core's streams.

    Returns the scalar partial sum alpha_s = <u, v> over the whole
    per-core stream, streamed through VMEM in C-sized tokens.
    """
    (n,) = u.shape
    assert v.shape == (n,)
    assert n % token == 0, "stream length must be a multiple of the token size"
    m = n // token

    kernel = functools.partial(_streamed_inprod_kernel, num_tokens=m)
    out = pl.pallas_call(
        kernel,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((token,), lambda i: (i,)),
            pl.BlockSpec((token,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(u, v)
    return out[0]
