"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy only. pytest (python/tests/) asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-generated
shape/value sweeps — this is the core correctness signal for L1.

The semantics mirror the paper's per-hyperstep token compute:

* ``token_mm_acc``    — the Cannon inner step: C_ij += A_ik · B_kj on
  k×k blocks resident in core-local memory (paper §3.2).
* ``inprod_partial``  — Algorithm 1's per-token partial sum:
  alpha_s += sigma_v · sigma_u (paper §3.1).
* ``streamed_matmul`` — the full multi-level product, i.e. what the
  M³ hypersteps of Algorithm 2 compute end to end.
* ``axpy``            — y += alpha·x, the per-frame compute of the §7
  video-pipeline example.
* ``spmv_ell``        — ELLPACK sparse matrix–vector product, the §7
  sparse extension.
"""

import jax.numpy as jnp


def token_mm_acc(c, a, b):
    """Return c + a @ b (f32 accumulate)."""
    return c + jnp.dot(a, b, preferred_element_type=jnp.float32)


def inprod_partial(acc, u, v):
    """Return acc + <u, v> as a scalar f32."""
    return acc + jnp.dot(u, v, preferred_element_type=jnp.float32)


def streamed_matmul(a, b):
    """Return a @ b (f32)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def axpy(alpha, x, y):
    """Return y + alpha * x."""
    return y + alpha * x


def spmv_ell(values, cols, x):
    """ELLPACK SpMV: y[i] = sum_j values[i, j] * x[cols[i, j]].

    ``cols`` entries equal to -1 denote padding and contribute zero
    (their value slot is also zero by construction, but we mask anyway).
    """
    gathered = x[jnp.clip(cols, 0, x.shape[0] - 1)]
    mask = (cols >= 0).astype(values.dtype)
    return jnp.sum(values * gathered * mask, axis=1)
