"""L1 Pallas kernels for the Cannon token compute (paper §3.2).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper streams
k×k matrix blocks ("tokens") from shared DRAM into each core's 32 KB
scratchpad via DMA, overlapping the fetch with the block product of the
current hyperstep. On a TPU-shaped machine the same insight maps onto the
Pallas execution model: BlockSpec describes the HBM→VMEM token schedule,
the grid plays the role of the hyperstep loop, and Pallas's implicit
double buffering is the paper's asynchronous DMA prefetch.

Two kernels:

* ``token_mm_acc``   — a single hyperstep's compute: C += A·B on one
  resident block triple. This is what the rust coordinator dispatches
  per (core, hyperstep) through PJRT.
* ``streamed_matmul`` — the whole Algorithm 2 collapsed into one grid:
  an (M, M, M)-grid blocked matmul whose index maps reproduce the
  paper's stream orders (Σ^A row-major revisited M times, Σ^B
  column-major looped M times) and whose resident output block is the
  C-token that Algorithm 2 writes up every M hypersteps.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers to plain HLO that
the rust runtime loads unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_acc_kernel(c_ref, a_ref, b_ref, o_ref):
    """o = c + a @ b on blocks already resident in VMEM."""
    o_ref[...] = c_ref[...] + jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def token_mm_acc(c, a, b):
    """One Cannon hyperstep: return ``c + a @ b`` for k×k f32 blocks.

    The block is a *token* in the paper's sense: it must fit in core-local
    memory. k is static; the rust side picks the executable compiled for
    its block size (artifacts/token_mm_acc_k*.hlo.txt).
    """
    k = c.shape[0]
    assert c.shape == (k, k) and a.shape == (k, k) and b.shape == (k, k)
    return pl.pallas_call(
        _mm_acc_kernel,
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.float32),
        interpret=True,
    )(c, a, b)


def _streamed_mm_kernel(a_ref, b_ref, o_ref, *, num_k):
    """Grid-streamed blocked matmul accumulating into the resident C block.

    Grid = (M, M, M) over (i, j, k). The k axis is innermost, so the
    output block for (i, j) stays resident in VMEM across the k-sweep and
    is complete when k == M-1 — exactly Algorithm 2's "after every M
    hypersteps we have completely computed one block of C".
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def streamed_matmul(a, b, *, block: int = 16):
    """Full multi-level product A·B via one Pallas grid.

    BlockSpec index maps mirror the paper's streams:
      * A block (i, k)   — row-major outer blocks, each revisited for
        every j (the ``↻ M times`` in Σ^A),
      * B block (k, j)   — column-major outer blocks, looped once per i
        (the ``↻ M times`` around all of Σ^B).
    """
    n, n2 = a.shape
    nb, n3 = b.shape
    assert n == n2 == nb == n3, "square matrices only"
    assert n % block == 0, "matrix size must be a multiple of the block"
    m = n // block  # the paper's M: number of outer blocks per dimension

    kernel = functools.partial(_streamed_mm_kernel, num_k=m)
    return pl.pallas_call(
        kernel,
        grid=(m, m, m),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, k: (i, k)),
            pl.BlockSpec((block, block), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(a, b)
