"""L2 — JAX compute graphs for the per-token work (build-time only).

Each function here is a complete hyperstep compute that the rust
coordinator dispatches through PJRT. They call the L1 Pallas kernels so
the kernels lower into the same HLO module.

Conventions shared with the rust runtime (rust/src/runtime/):

* All scalars travel as shape-(1,) f32 arrays — PJRT literal marshaling
  stays uniform (every input/output is an array).
* Every entry point returns a tuple (lowered with ``return_tuple=True``);
  the rust side unwraps with ``to_tuple1()``.
* Shapes are static; one artifact is emitted per (entry point, shape)
  combination used by the benches. The catalog lives in aot.py.
"""

import jax.numpy as jnp

from .kernels import axpy as _axpy
from .kernels import inner_product as _ip
from .kernels import matmul_block as _mm
from .kernels import spmv as _spmv


def token_mm_acc(c, a, b):
    """Cannon hyperstep: C_token += A_token · B_token (paper Alg. 2)."""
    return (_mm.token_mm_acc(c, a, b),)


def streamed_matmul_b16(a, b):
    """Whole multi-level matmul as one grid-streamed kernel (block=16)."""
    return (_mm.streamed_matmul(a, b, block=16),)


def inprod_partial(acc, u, v):
    """Inner-product hyperstep: alpha_s += <sigma_u, sigma_v> (Alg. 1).

    ``acc`` is shape (1,); the kernel consumes/produces a scalar which we
    re-wrap so the artifact I/O is uniform arrays.
    """
    out = _ip.inprod_partial(acc[0], u, v)
    return (jnp.reshape(out, (1,)),)


def streamed_inprod_c64(u, v):
    """Whole per-core token loop of Algorithm 1 (token size 64)."""
    out = _ip.streamed_inprod(u, v, token=64)
    return (jnp.reshape(out, (1,)),)


def axpy(alpha, x, y):
    """Video-pipeline frame filter: y + alpha·x (paper §7)."""
    return (_axpy.axpy(alpha, x, y),)


def spmv_ell(values, cols, x):
    """Sparse extension: ELLPACK SpMV row-block token (paper §7)."""
    return (_spmv.spmv_ell(values, cols, x),)
