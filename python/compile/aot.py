"""AOT compiler: lower every L2 entry point to HLO text artifacts.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts

Interchange format is **HLO text**, not a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``
so the rust side always unwraps a 1-tuple.

Besides the ``<name>.hlo.txt`` files this writes ``manifest.txt``, one
line per artifact::

    name|in=f32[8,8];f32[8,8]|out=f32[8,8]

which the rust artifact registry parses to know each executable's
signature without touching the HLO.
"""

import argparse
import os

import jax
import jax.numpy as jnp


from . import model

F32 = jnp.float32
I32 = jnp.int32


def _s(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def catalog():
    """The artifact catalog: (name, fn, example_args).

    Shapes correspond to the block/token sizes exercised by the rust
    benches (see DESIGN.md per-experiment index). Block sizes follow
    Fig. 5's k sweep; token sizes follow the Algorithm 1 analysis.
    """
    entries = []
    for k in (4, 8, 16, 32):
        entries.append(
            (f"token_mm_acc_k{k}", model.token_mm_acc,
             [_s((k, k)), _s((k, k)), _s((k, k))])
        )
    for c in (64, 256, 1024):
        entries.append(
            (f"inprod_partial_c{c}", model.inprod_partial,
             [_s((1,)), _s((c,)), _s((c,))])
        )
    entries.append(
        ("streamed_inprod_n4096_c64", model.streamed_inprod_c64,
         [_s((4096,)), _s((4096,))])
    )
    entries.append(
        ("streamed_mm_n64_b16", model.streamed_matmul_b16,
         [_s((64, 64)), _s((64, 64))])
    )
    for n in (1024, 4096):
        entries.append(
            (f"axpy_n{n}", model.axpy, [_s((1,)), _s((n,)), _s((n,))])
        )
    entries.append(
        ("spmv_ell_r64_nnz8_n64", model.spmv_ell,
         [_s((64, 8)), _s((64, 8), I32), _s((64,))])
    )
    return entries


def to_hlo_text(lowered) -> str:
    """Lowered jaxpr → HLO text (see module docstring).

    We go through ``compiler_ir(dialect="hlo")`` which yields an
    XlaComputation directly. (The alternative StableHLO-text →
    ``mlir_module_to_xla_computation`` route trips over a printer/parser
    skew for interpret-mode pallas modules containing dynamic_slice.)
    Single-output entry points lower to a plain array root, so the rust
    side reads the result literal directly — no tuple unwrap.
    """
    return lowered.compiler_ir(dialect="hlo").as_hlo_text()


def _sig(spec) -> str:
    dt = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[spec.dtype]
    dims = ",".join(str(d) for d in spec.shape)
    return f"{dt}[{dims}]"


def build(out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    for name, fn, args in catalog():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *args)
        in_sig = ";".join(_sig(a) for a in args)
        out_sig = ";".join(_sig(o) for o in outs)
        manifest_lines.append(f"{name}|in={in_sig}|out={out_sig}")
        print(f"  {name}: {len(text)} chars, in={in_sig} out={out_sig}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return manifest_lines


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    args = parser.parse_args()
    lines = build(args.out)
    print(f"wrote {len(lines)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
