"""AOT pipeline tests: catalog integrity, manifest grammar, HLO emission."""

import os
import re

import pytest

from compile import aot

MANIFEST_RE = re.compile(
    r"^[a-z0-9_]+\|in=((f32|i32)\[[0-9,]*\];?)+\|out=((f32|i32)\[[0-9,]*\];?)+$"
)


def test_catalog_names_unique():
    names = [name for name, _, _ in aot.catalog()]
    assert len(names) == len(set(names))


def test_catalog_covers_required_entry_points():
    names = {name for name, _, _ in aot.catalog()}
    # The rust benches depend on these exact names (runtime::artifact).
    for required in [
        "token_mm_acc_k4", "token_mm_acc_k8", "token_mm_acc_k16",
        "token_mm_acc_k32", "inprod_partial_c64", "streamed_mm_n64_b16",
        "axpy_n4096", "spmv_ell_r64_nnz8_n64",
    ]:
        assert required in names, required


def test_sig_format():
    import jax, jax.numpy as jnp

    assert aot._sig(jax.ShapeDtypeStruct((8, 8), jnp.float32)) == "f32[8,8]"
    assert aot._sig(jax.ShapeDtypeStruct((64,), jnp.int32)) == "i32[64]"
    assert aot._sig(jax.ShapeDtypeStruct((1,), jnp.float32)) == "f32[1]"


def test_build_single_entry_emits_parseable_hlo(tmp_path):
    """Lower one entry end to end and sanity-check the HLO text."""
    import jax

    name, fn, args = aot.catalog()[0]
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # f32[4,4] params appear in the entry layout
    assert "f32[4,4]" in text


@pytest.mark.skipif(
    os.environ.get("BSPS_SKIP_SLOW") == "1", reason="slow: full catalog build"
)
def test_full_build_manifest_grammar(tmp_path):
    lines = aot.build(str(tmp_path))
    assert len(lines) == len(aot.catalog())
    for line in lines:
        assert MANIFEST_RE.match(line), line
    for name, _, _ in aot.catalog():
        assert (tmp_path / f"{name}.hlo.txt").exists()
