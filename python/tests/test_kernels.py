"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and values; assert_allclose against ref. This is
the core correctness signal for the kernels that end up inside the AOT
artifacts the rust runtime executes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import axpy, inner_product, matmul_block, ref, spmv

F32 = np.float32

finite = st.floats(
    min_value=-1e3, max_value=1e3, allow_nan=False, width=32
)


def arrays(shape):
    """Random f32 arrays, seeded by hypothesis (drawing whole large lists
    element-wise trips the large_base_example health check)."""
    return st.integers(min_value=0, max_value=2**32 - 1).map(
        lambda seed: np.random.default_rng(seed)
        .uniform(-100.0, 100.0, size=shape)
        .astype(F32)
    )


# ---------------------------------------------------------------- matmul

@settings(max_examples=25, deadline=None)
@given(st.data(), st.sampled_from([2, 3, 4, 8, 16]))
def test_token_mm_acc_matches_ref(data, k):
    c = data.draw(arrays((k, k)))
    a = data.draw(arrays((k, k)))
    b = data.draw(arrays((k, k)))
    got = matmul_block.token_mm_acc(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b))
    want = ref.token_mm_acc(c, a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.data(), st.sampled_from([(16, 4), (16, 8), (32, 8), (48, 16)]))
def test_streamed_matmul_matches_ref(data, nb):
    n, block = nb
    a = data.draw(arrays((n, n)))
    b = data.draw(arrays((n, n)))
    got = matmul_block.streamed_matmul(jnp.asarray(a), jnp.asarray(b), block=block)
    want = ref.streamed_matmul(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-2)


def test_streamed_matmul_identity():
    n = 32
    eye = np.eye(n, dtype=F32)
    a = np.arange(n * n, dtype=F32).reshape(n, n) / n
    got = matmul_block.streamed_matmul(jnp.asarray(a), jnp.asarray(eye), block=8)
    np.testing.assert_allclose(np.asarray(got), a, rtol=1e-5)


def test_streamed_matmul_rejects_non_divisible():
    a = jnp.zeros((10, 10), jnp.float32)
    with pytest.raises(AssertionError):
        matmul_block.streamed_matmul(a, a, block=3)


# ----------------------------------------------------------- inner product

@settings(max_examples=25, deadline=None)
@given(st.data(), st.sampled_from([1, 4, 64, 256]), finite)
def test_inprod_partial_matches_ref(data, c, acc):
    u = data.draw(arrays((c,)))
    v = data.draw(arrays((c,)))
    acc = F32(acc)
    got = inner_product.inprod_partial(jnp.asarray(acc), jnp.asarray(u), jnp.asarray(v))
    want = ref.inprod_partial(acc, u, v)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-3, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(st.data(), st.sampled_from([(64, 16), (128, 32), (256, 64)]))
def test_streamed_inprod_matches_ref(data, nt):
    n, token = nt
    u = data.draw(arrays((n,)))
    v = data.draw(arrays((n,)))
    got = inner_product.streamed_inprod(jnp.asarray(u), jnp.asarray(v), token=token)
    np.testing.assert_allclose(
        float(got), float(np.dot(u, v)), rtol=1e-3, atol=1e-1
    )


def test_streamed_inprod_zero():
    u = jnp.zeros((128,), jnp.float32)
    assert float(inner_product.streamed_inprod(u, u, token=32)) == 0.0


# ------------------------------------------------------------------- axpy

@settings(max_examples=20, deadline=None)
@given(st.data(), st.sampled_from([(32, 8), (64, 64), (128, 32)]), finite)
def test_axpy_matches_ref(data, nt, alpha):
    n, token = nt
    x = data.draw(arrays((n,)))
    y = data.draw(arrays((n,)))
    alpha = F32(alpha)
    got = axpy.axpy(
        jnp.asarray([alpha]), jnp.asarray(x), jnp.asarray(y), token=token
    )
    want = ref.axpy(alpha, x, y)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-2)


# ------------------------------------------------------------------- spmv

@settings(max_examples=15, deadline=None)
@given(st.data(), st.sampled_from([(8, 2, 8), (16, 4, 16), (64, 8, 64)]))
def test_spmv_ell_matches_ref(data, spec):
    rows, nnz, n = spec
    vals = data.draw(arrays((rows, nnz)))
    x = data.draw(arrays((n,)))
    cols_flat = data.draw(
        st.lists(
            st.integers(min_value=-1, max_value=n - 1),
            min_size=rows * nnz, max_size=rows * nnz,
        )
    )
    cols = np.asarray(cols_flat, dtype=np.int32).reshape(rows, nnz)
    vals = vals * (cols >= 0)  # padding slots carry zero values
    got = spmv.spmv_ell(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    want = ref.spmv_ell(vals, cols, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-2)


def test_spmv_ell_dense_equivalence():
    """A fully-dense ELL token must equal the dense matvec."""
    rng = np.random.default_rng(7)
    n = 16
    dense = rng.standard_normal((n, n)).astype(F32)
    cols = np.tile(np.arange(n, dtype=np.int32), (n, 1))
    x = rng.standard_normal(n).astype(F32)
    got = spmv.spmv_ell(jnp.asarray(dense), jnp.asarray(cols), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), dense @ x, rtol=1e-4, atol=1e-4)
