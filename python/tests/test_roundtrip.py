"""AOT artifact integrity: every catalog entry's emitted HLO text must
carry the exact entry signature the manifest advertises, and the traced
function must be numerically sane on concrete inputs.

(The text→PJRT→execute leg of the round trip runs on the rust side —
`rust/src/runtime/engine.rs` tests and the e2e example — because this
image's jaxlib cannot parse HLO text back; the checks here pin down the
Python half: what we emit is what the manifest promises.)
"""

import re

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot

rng = np.random.default_rng(2016)


def _concrete(spec):
    if spec.dtype == jnp.int32:
        hi = max(spec.shape[-1] if spec.shape else 4, 2)
        return rng.integers(-1, hi, size=spec.shape).astype(np.int32)
    return rng.standard_normal(spec.shape).astype(np.float32)


@pytest.mark.parametrize("entry", [e[0] for e in aot.catalog()])
def test_emitted_hlo_signature_matches_manifest(entry):
    name, fn, args = next(e for e in aot.catalog() if e[0] == entry)
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # The entry computation layout lists every parameter with its shape.
    layout = re.search(r"entry_computation_layout=\{\((.*?)\)->", text)
    assert layout, f"{name}: no entry layout in HLO text"
    params = layout.group(1)
    for a in args:
        dt = {jnp.float32.dtype: "f32", jnp.int32.dtype: "s32"}[a.dtype]
        dims = ",".join(str(d) for d in a.shape)
        assert f"{dt}[{dims}]" in params, f"{name}: missing {dt}[{dims}] in {params}"
    # Output signature too.
    out_sig = text[layout.end():].split("}", 1)[0]
    (out,) = jax.eval_shape(fn, *args)
    out_dims = ",".join(str(d) for d in out.shape)
    assert f"f32[{out_dims}]" in out_sig, f"{name}: bad output layout {out_sig}"


@pytest.mark.parametrize("entry", [e[0] for e in aot.catalog()])
def test_entry_point_numerics_finite(entry):
    name, fn, args = next(e for e in aot.catalog() if e[0] == entry)
    concrete = [_concrete(a) for a in args]
    (out,) = fn(*[jnp.asarray(c) for c in concrete])
    assert np.all(np.isfinite(np.asarray(out))), f"{name}: non-finite output"


def test_catalog_shapes_are_pjrt_friendly():
    """All inputs/outputs are plain arrays (no tuples, no scalars) so the
    rust literal marshalling stays uniform."""
    for name, fn, args in aot.catalog():
        outs = jax.eval_shape(fn, *args)
        assert isinstance(outs, tuple) and len(outs) == 1, name
        assert outs[0].shape != (), f"{name}: scalar output"
        for a in args:
            assert a.shape != (), f"{name}: scalar input"


def test_block_sizes_cover_fig5_sweep():
    """The mm_acc catalog must cover every k the Fig. 5 executed points
    use (4, 8, 16, 32)."""
    names = {e[0] for e in aot.catalog()}
    for k in (4, 8, 16, 32):
        assert f"token_mm_acc_k{k}" in names
