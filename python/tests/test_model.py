"""L2 correctness: model entry points — shapes, dtypes, and numerics.

These are the exact functions the AOT catalog lowers; anything asserted
here holds for the artifacts the rust runtime executes.
"""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

F32 = np.float32
rng = np.random.default_rng(42)


def _r(*shape):
    return rng.standard_normal(shape).astype(F32)


def test_token_mm_acc_tuple_shape():
    c, a, b = _r(8, 8), _r(8, 8), _r(8, 8)
    (out,) = model.token_mm_acc(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b))
    assert out.shape == (8, 8) and out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), ref.token_mm_acc(c, a, b), rtol=1e-4)


def test_inprod_partial_scalar_as_1vec():
    acc, u, v = np.asarray([1.5], dtype=F32), _r(64), _r(64)
    (out,) = model.inprod_partial(jnp.asarray(acc), jnp.asarray(u), jnp.asarray(v))
    assert out.shape == (1,)
    np.testing.assert_allclose(
        float(out[0]), float(ref.inprod_partial(acc[0], u, v)), rtol=1e-4
    )


def test_streamed_inprod_c64():
    u, v = _r(4096), _r(4096)
    (out,) = model.streamed_inprod_c64(jnp.asarray(u), jnp.asarray(v))
    assert out.shape == (1,)
    np.testing.assert_allclose(float(out[0]), float(u @ v), rtol=1e-3)


def test_streamed_matmul_b16():
    a, b = _r(64, 64), _r(64, 64)
    (out,) = model.streamed_matmul_b16(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-3, atol=1e-3)


def test_axpy():
    alpha, x, y = np.asarray([0.25], dtype=F32), _r(1024), _r(1024)
    (out,) = model.axpy(jnp.asarray(alpha), jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(out), ref.axpy(alpha[0], x, y), rtol=1e-5)


def test_spmv_ell():
    vals = _r(64, 8)
    cols = rng.integers(-1, 64, size=(64, 8)).astype(np.int32)
    vals = vals * (cols >= 0)
    x = _r(64)
    (out,) = model.spmv_ell(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.spmv_ell(vals, cols, x)), rtol=1e-3, atol=1e-3
    )


def test_entry_points_jit_stable():
    """Every catalog entry must lower under jit with static shapes."""
    from compile.aot import catalog

    for name, fn, args in catalog():
        jax.jit(fn).lower(*args)  # raises on failure
