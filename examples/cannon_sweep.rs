//! Regenerate **Figure 5**: run time of multi-level Cannon on the
//! Epiphany-III vs the inner block size `k = n/(N·M)`, for several
//! matrix sizes, with the compute/bandwidth crossover `k_equal` marked.
//!
//! Two series per matrix size:
//! * `sim`  — the exact Eq. 1 ledger of the executed loop, produced by
//!   the pure cost walk (`algos::cannon_ml::simulate_cost`) so the full
//!   `k` range is covered without hour-long gang runs;
//! * `exec` — the real SPMD gang with real data (numerics verified),
//!   for the points whose `M³` hyperstep count is tractable; printed to
//!   show sim ≡ exec.
//!
//! ```sh
//! cargo run --release --offline --example cannon_sweep
//! cargo run --release --offline --example cannon_sweep -- --verify-cost
//! ```

use bsps::algos::{baselines, cannon_ml};
use bsps::coordinator::BspsEnv;
use bsps::model::params::AcceleratorParams;
use bsps::model::predict;
use bsps::util::humanfmt::seconds;
use bsps::util::prng::SplitMix64;

fn main() -> bsps::util::error::Result<()> {
    let machine = AcceleratorParams::epiphany3();
    let grid_n = machine.grid_n();
    let verify = std::env::args().any(|a| a == "--verify-cost");

    println!(
        "# Figure 5: multi-level Cannon run time vs k on {} (N={grid_n})",
        machine.name
    );
    println!("# k_equal (paper §6): {:.2}  (paper: ≈ 8)", predict::k_equal(&machine));
    println!(
        "{:>5} {:>5} {:>6} {:>12} {:>12} {:>12} {:>10}",
        "n", "k", "M", "sim", "Eq.2", "exec", "side"
    );

    for n in [128usize, 256, 512] {
        for k in [1usize, 2, 4, 8, 16, 32] {
            if n % (grid_n * k) != 0 {
                continue;
            }
            let m = n / (grid_n * k);
            let ledger = cannon_ml::simulate_cost(&machine, n, m)?;
            let sim = ledger.summarize(&machine);
            let pred = predict::cannon_cost(&machine, n, m);

            // Execute with real data where M³ stays tractable.
            let exec = if m * m * m <= 512 {
                let mut rng = SplitMix64::new(n as u64);
                let a = rng.f32_vec(n * n, -1.0, 1.0);
                let b = rng.f32_vec(n * n, -1.0, 1.0);
                let env = BspsEnv::native(machine.clone());
                let run = cannon_ml::run(&env, &a, &b, n, m)?;
                // Verify numerics against the sequential baseline.
                let (want, _) = baselines::seq_matmul(&a, &b, n);
                let max_err = run
                    .c
                    .iter()
                    .zip(&want)
                    .map(|(g, w)| (g - w).abs())
                    .fold(0.0f32, f32::max);
                assert!(max_err < 0.3, "numerics diverged: {max_err}");
                Some(run.report.sim_seconds)
            } else {
                None
            };

            println!(
                "{:>5} {:>5} {:>6} {:>12} {:>12} {:>12} {:>10}",
                n,
                k,
                m,
                seconds(sim.total_seconds),
                seconds(pred.seconds),
                exec.map(seconds).unwrap_or_else(|| "-".into()),
                if pred.bandwidth_heavy { "bandwidth" } else { "compute" },
            );

            if verify {
                if let Some(exec_s) = exec {
                    let rel = (exec_s - sim.total_seconds).abs() / exec_s;
                    println!("        cost-walk vs executed: rel err {rel:.2e}");
                }
            }
        }
        println!();
    }
    println!("# paper shape: larger M (smaller k) -> higher run time;");
    println!("# block size should be chosen as large as local memory allows.");
    Ok(())
}
