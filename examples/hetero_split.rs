//! §7's closing question: *"develop models that use the BSP and BSPS
//! costs to distribute the work of a single algorithm in this
//! heterogeneous environment"* — answered end to end.
//!
//! Scenario: one Epiphany-III and one Xeon-Phi-class accelerator share
//! a divisible streaming inner-product workload. The optimal split
//! follows each unit's BSPS throughput, which depends on the workload's
//! arithmetic intensity `I` (FLOPs per word streamed): at low `I` both
//! units are fetch-bound and the split follows link bandwidth; at high
//! `I` it follows raw compute. After sweeping the model, the example
//! *executes* the split: `hetero_split_jobs` quantizes the fluid
//! fractions onto grain boundaries, one gang per unit runs its share
//! concurrently through the class-matched scheduler, and the measured
//! virtual makespan is checked against the best single unit running the
//! whole workload alone.
//!
//! ```sh
//! cargo run --release --offline --example hetero_split
//! ```

use bsps::bsp::sched::hetero_split_jobs;
use bsps::model::hetero::{makespan, optimal_split, unit_throughput};
use bsps::model::params::AcceleratorParams;
use bsps::util::humanfmt::seconds;

fn main() {
    let units = vec![AcceleratorParams::epiphany3(), AcceleratorParams::xeonphi_like()];
    let w = 1.0e10; // 10 GFLOP of divisible streaming work

    println!("units: {} + {}", units[0].name, units[1].name);
    println!(
        "{:>10} {:>14} {:>14} {:>18} {:>12} {:>12}",
        "I (F/word)", "epi3 rate", "phi rate", "split (epi3/phi)", "optimal", "even split"
    );
    for intensity in [2.0, 8.0, 43.4, 200.0, 2000.0] {
        let r0 = unit_throughput(&units[0], intensity);
        let r1 = unit_throughput(&units[1], intensity);
        let (fractions, best) = optimal_split(&units, intensity, w);
        let even = makespan(&units, intensity, w, &[0.5, 0.5]);
        println!(
            "{:>10} {:>12.2e}/s {:>12.2e}/s {:>8.4} / {:<8.4} {:>12} {:>12}",
            intensity,
            r0,
            r1,
            fractions[0],
            fractions[1],
            seconds(best),
            seconds(even),
        );
        assert!(best <= even + 1e-12);
    }
    println!(
        "\nNote the intensity crossovers: each unit flips from fetch-bound to\n\
         compute-bound at I = its own e ({} and {}), reshaping the split —\n\
         the BSPS classification driving scheduling, as §7 envisions.\n",
        units[0].e, units[1].e
    );

    // Now run one of those splits for real: I = 50 puts the Epiphany
    // just past its compute-bound crossover while the Phi stays far
    // under its own, so the shares are wildly uneven — exactly the
    // regime where grain quantization must be careful to still beat
    // the fastest unit going it alone.
    let intensity = 50.0;
    let run = hetero_split_jobs(&units, intensity, 5.0e8).run();
    print!("{}", run.render());
    assert!(run.byte_identical(), "scheduled shares diverged from serial");
    assert!(
        run.makespan_virtual_seconds < run.best_solo_seconds(),
        "the split ({}) must beat the best solo unit ({})",
        seconds(run.makespan_virtual_seconds),
        seconds(run.best_solo_seconds()),
    );
    println!(
        "\nThe scheduled split finished in {} of virtual time — ahead of the\n\
         fastest single unit ({}), within {:.1}% of the Eq. 1 prediction.",
        seconds(run.makespan_virtual_seconds),
        seconds(run.best_solo_seconds()),
        run.pred_rel_err() * 100.0,
    );
}
