//! Regenerate the paper's raw-measurement artifacts from the simulated
//! hardware: **Table 1** (per-core speeds to shared memory) and
//! **Figure 4** (single-core speed vs transfer size), plus the §5
//! parameter fit that turns them into `(e, g, l)`.
//!
//! ```sh
//! cargo run --release --offline --example memspeed            # Table 1 + fit
//! cargo run --release --offline --example memspeed -- --figure4
//! ```

use bsps::model::calibrate;
use bsps::sim::extmem::{Actor, Dir, ExtMemModel, NetState};
use bsps::sim::membench;
use bsps::sim::noc::Noc;
use bsps::util::humanfmt::mbps;

fn main() {
    let mem = ExtMemModel::epiphany3();
    let figure4 = std::env::args().any(|a| a == "--figure4");

    if figure4 {
        println!("# Figure 4: single core, free network (speeds in MB/s)");
        println!("{:>10} {:>12} {:>12} {:>14}", "bytes", "read", "write", "write+burst");
        for p in membench::fig4(&mem) {
            println!(
                "{:>10} {:>12.2} {:>12.2} {:>14.2}",
                p.bytes,
                p.read_bps / 1e6,
                p.write_bps / 1e6,
                p.write_burst_bps / 1e6
            );
        }
        return;
    }

    println!("# Table 1: communication speeds to shared memory (per core)");
    println!("{:<6} {:<10} {:>12} {:>12}", "Actor", "Network", "Read", "Write");
    let paper = [
        ("Core", "contested", 8.3, 14.1),
        ("Core", "free", 8.9, 270.0),
        ("DMA", "contested", 11.0, 12.1),
        ("DMA", "free", 80.0, 230.0),
    ];
    for (row, (actor, state, p_read, p_write)) in
        membench::table1(&mem).iter().zip(paper)
    {
        println!(
            "{:<6} {:<10} {:>12} {:>12}   (paper: {p_read} / {p_write} MB/s)",
            actor,
            state,
            mbps(row.read_bps),
            mbps(row.write_bps)
        );
    }

    println!("\n# §5 parameter fit from these measurements");
    let noc = Noc::epiphany3(4);
    let samples = membench::comm_sweep(&noc, 512, 8);
    let contested = mem.bandwidth(Actor::Dma, Dir::Read, NetState::Contested);
    let cal = calibrate::calibrate(120.0e6, contested, &samples, 0.0);
    println!("e = {:.2} FLOP/float   (paper: ≈ 43.4)", cal.e);
    println!("g = {:.3} FLOP/float  (paper: ≈ 5.59)", cal.g);
    println!("l = {:.1} FLOP        (paper: ≈ 136)", cal.l);
}
