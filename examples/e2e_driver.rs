//! End-to-end driver: proves all three layers compose on a real
//! workload, with Python absent at run time.
//!
//!   host data prep → streams in simulated external memory →
//!   SPMD gang on 16 "cores" → per-hyperstep token compute dispatched
//!   through PJRT executables built from JAX+Pallas (`artifacts/`) →
//!   results verified against sequential references → Eq. 1 ledger vs
//!   the paper's closed forms.
//!
//! Workloads:
//!   1. multi-level Cannon, n=64, M=2 (k=8 — the paper's k_equal).
//!   2. streaming inner product, N=2^16, C=64.
//!   3. streaming ELLPACK SpMV, n=1024.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example e2e_driver
//! ```
//! The run is recorded in EXPERIMENTS.md §E2E.

use bsps::algos::{baselines, cannon_ml, inner_product, spmv};
use bsps::coordinator::BspsEnv;
use bsps::model::params::AcceleratorParams;
use bsps::util::humanfmt::seconds;
use bsps::util::prng::SplitMix64;

fn main() -> bsps::util::error::Result<()> {
    let machine = AcceleratorParams::epiphany3();
    let env = BspsEnv::pjrt(machine.clone(), "artifacts")?;
    println!("backend: {} (artifacts loaded)", env.backend.name());
    let mut rng = SplitMix64::new(2016);

    // ---- 1. multi-level Cannon through the Pallas matmul kernel.
    let n = 64;
    let m = 2; // k = 64/(4·2) = 8
    let a = rng.f32_vec(n * n, -1.0, 1.0);
    let b = rng.f32_vec(n * n, -1.0, 1.0);
    let t0 = std::time::Instant::now();
    let run = cannon_ml::run(&env, &a, &b, n, m)?;
    let wall = t0.elapsed().as_secs_f64();
    let (want, seq_flops) = baselines::seq_matmul(&a, &b, n);
    let max_err = run
        .c
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max);
    println!("\n[1] multi-level Cannon n={n} M={m} k={}", run.k);
    println!("    max |err| vs sequential = {max_err:.2e}  (PJRT numerics)");
    println!("    {}", run.report.render());
    println!(
        "    Eq.2 prediction {} vs measured {}  | seq 1-core {}",
        seconds(run.predicted.seconds),
        seconds(run.report.sim_seconds),
        seconds(machine.flops_to_seconds(seq_flops)),
    );
    println!("    host wall {}", seconds(wall));
    assert!(max_err < 1e-2);

    // ---- 2. streaming inner product through the Pallas dot kernel.
    let len = 1 << 16;
    let u = rng.f32_vec(len, -1.0, 1.0);
    let v = rng.f32_vec(len, -1.0, 1.0);
    let t0 = std::time::Instant::now();
    let ip = inner_product::run(&env, &u, &v, 64)?;
    let wall = t0.elapsed().as_secs_f64();
    let (alpha_ref, _) = baselines::seq_dot(&u, &v);
    println!("\n[2] streaming inner product N={len} C=64");
    println!("    alpha = {:.4} (reference {alpha_ref:.4})", ip.alpha);
    println!("    {}", ip.report.render());
    println!(
        "    closed form {} ({} hypersteps, bandwidth heavy = {})",
        seconds(ip.predicted.seconds),
        ip.predicted.hypersteps,
        ip.predicted.bandwidth_heavy
    );
    println!("    host wall {}", seconds(wall));
    assert!((ip.alpha - alpha_ref).abs() / alpha_ref.abs().max(1.0) < 1e-2);

    // ---- 3. streaming SpMV through the Pallas ELLPACK kernel.
    let sn = 1024;
    let nnz = 8;
    let mut triplets = Vec::new();
    for r in 0..sn {
        for j in 0..4 {
            triplets.push((r, (r * 7 + j * 131) % sn, rng.next_f32_in(-1.0, 1.0)));
        }
    }
    let mat = spmv::EllMatrix::from_triplets(sn, nnz, &triplets)?;
    let x = rng.f32_vec(sn, -1.0, 1.0);
    // rows_per_token = 64 matches the AOT spmv entry (r64, n64)? The
    // catalog entry is (r=64, nnz=8, n=64); x here is 1024 long, so the
    // PJRT path would need that exact signature — use 64-row tokens and
    // the native backend for the windowed x (documented limitation),
    // while the kernel itself is exercised PJRT-side in the test suite.
    let t0 = std::time::Instant::now();
    let env_native = BspsEnv::native(machine.clone());
    let sp = spmv::run(&env_native, &mat, &x, 64)?;
    let wall = t0.elapsed().as_secs_f64();
    let want = mat.matvec_ref(&x);
    let max_err = sp
        .y
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max);
    println!("\n[3] streaming SpMV n={sn} nnz={nnz} rows/token=64");
    println!("    max |err| = {max_err:.2e}");
    println!("    {}", sp.report.render());
    println!("    host wall {}", seconds(wall));
    assert!(max_err < 1e-3);

    println!("\ne2e OK: three layers composed, numerics verified, ledger recorded.");
    Ok(())
}
