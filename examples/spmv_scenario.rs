//! The §7 sparse extension as a standalone scenario: a 2D 5-point
//! Laplacian stencil matrix (the classic scientific-computing workload)
//! streamed through the accelerator in ELLPACK row-block tokens.
//!
//! ```sh
//! cargo run --release --offline --example spmv_scenario
//! ```

use bsps::algos::spmv::{run, EllMatrix};
use bsps::coordinator::BspsEnv;
use bsps::model::params::AcceleratorParams;
use bsps::util::humanfmt::seconds;
use bsps::util::prng::SplitMix64;

/// 5-point Laplacian on a `side × side` grid.
fn laplacian(side: usize) -> EllMatrix {
    let n = side * side;
    let mut triplets = Vec::new();
    for row in 0..side {
        for col in 0..side {
            let i = row * side + col;
            triplets.push((i, i, 4.0f32));
            if row > 0 {
                triplets.push((i, i - side, -1.0));
            }
            if row + 1 < side {
                triplets.push((i, i + side, -1.0));
            }
            if col > 0 {
                triplets.push((i, i - 1, -1.0));
            }
            if col + 1 < side {
                triplets.push((i, i + 1, -1.0));
            }
        }
    }
    EllMatrix::from_triplets(n, 5, &triplets).expect("stencil fits nnz=5")
}

fn main() -> bsps::util::error::Result<()> {
    let machine = AcceleratorParams::epiphany3();
    let env = BspsEnv::native(machine.clone());
    let side = 64; // n = 4096
    let a = laplacian(side);
    let mut rng = SplitMix64::new(17);
    let x = rng.f32_vec(a.n, -1.0, 1.0);

    let run = run(&env, &a, &x, 16)?;
    let want = a.matvec_ref(&x);
    let max_err = run
        .y
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0f32, f32::max);

    println!("5-point Laplacian SpMV: n = {} (grid {side}×{side})", a.n);
    println!("max |err| vs reference = {max_err:.2e}");
    println!("{}", run.report.render());
    println!(
        "arithmetic intensity is ~2 FLOP/word: on e = {} every hyperstep \
         is bandwidth heavy — the sparse regime the paper's model flags \
         immediately (sim {} total).",
        machine.e,
        seconds(run.report.sim_seconds)
    );
    assert!(max_err < 1e-3);
    Ok(())
}
