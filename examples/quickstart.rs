//! Quickstart: the BSPS public API in ~40 lines.
//!
//! Computes an inner product with Algorithm 1 (paper §3.1) on the
//! simulated Epiphany-III, then — if `make artifacts` has run — repeats
//! it with the PJRT backend so the token compute goes through the AOT
//! Pallas kernel.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use bsps::algos::inner_product;
use bsps::coordinator::BspsEnv;
use bsps::model::params::AcceleratorParams;
use bsps::util::prng::SplitMix64;

fn main() -> bsps::util::error::Result<()> {
    // A machine: 16 cores, 32 KB scratchpads, e = 43.4 FLOP/float.
    let machine = AcceleratorParams::epiphany3();
    println!("machine: {} (p={}, e={})", machine.name, machine.p, machine.e);

    // A workload: two vectors of 2^16 f32s, streamed in 64-word tokens.
    let mut rng = SplitMix64::new(7);
    let n = 1 << 16;
    let u = rng.f32_vec(n, -1.0, 1.0);
    let v = rng.f32_vec(n, -1.0, 1.0);

    // Algorithm 1 on the native backend.
    let env = BspsEnv::native(machine.clone());
    let run = inner_product::run(&env, &u, &v, 64)?;
    let reference: f32 = u.iter().zip(&v).map(|(a, b)| a * b).sum();
    println!("native:  alpha = {:.4} (reference {reference:.4})", run.alpha);
    println!("         {}", run.report.render());
    println!(
        "         predicted: {} hypersteps, bandwidth heavy = {}",
        run.predicted.hypersteps, run.predicted.bandwidth_heavy
    );

    // Same thing through the three-layer path: rust -> PJRT -> XLA HLO
    // containing the interpret-mode Pallas kernel.
    match BspsEnv::pjrt(machine, "artifacts") {
        Ok(env_pjrt) => {
            let run = inner_product::run(&env_pjrt, &u, &v, 64)?;
            println!("pjrt:    alpha = {:.4}", run.alpha);
            println!("         {}", run.report.render());
        }
        Err(e) => println!("pjrt:    skipped ({e}) — run `make artifacts`"),
    }
    Ok(())
}
