//! The §7 motivating scenario: real-time video processing where each
//! hyperstep analyses one frame, and the BSPS cost function tells you
//! whether the feed can be processed in real time.
//!
//! The paper: "we could require the hypersteps to be bandwidth heavy to
//! ensure that we are able to process the entire video feed in real
//! time" — i.e. when the link is the bottleneck, the filter is free;
//! this driver shows the achievable simulated FPS on the Epiphany-III
//! link and on a GDDR-class link for comparison.
//!
//! ```sh
//! cargo run --release --offline --example video_pipeline
//! ```

use bsps::algos::video;
use bsps::coordinator::BspsEnv;
use bsps::model::params::AcceleratorParams;
use bsps::util::prng::SplitMix64;

fn main() -> bsps::util::error::Result<()> {
    let mut rng = SplitMix64::new(99);
    let frames = 32;
    let pixels = 16 * 1024; // 128×128 grayscale
    let fs: Vec<Vec<f32>> = (0..frames).map(|_| rng.f32_vec(pixels, 0.0, 255.0)).collect();

    for (label, machine) in [
        ("epiphany3 (e=43.4)", AcceleratorParams::epiphany3()),
        ("fast link (e=0.5)", {
            let mut m = AcceleratorParams::epiphany3();
            m.e = 0.5;
            m.name = "epiphany3-fastlink";
            m
        }),
    ] {
        let env = BspsEnv::native(machine);
        let run = video::run(&env, &fs, 0.25)?;
        // Verify against the reference filter.
        let want = video::filter_ref(&fs, 0.25);
        let max_err = run
            .output
            .iter()
            .flatten()
            .zip(want.iter().flatten())
            .map(|(g, w)| (g - w).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-2, "filter numerics diverged");

        println!("{label}:");
        println!("  {}", run.report.render());
        println!(
            "  simulated {:.1} fps | bandwidth heavy throughout = {} \
             (real-time headroom: filter work is {})",
            run.fps,
            run.bandwidth_heavy_throughout,
            if run.bandwidth_heavy_throughout { "free" } else { "the bottleneck" },
        );
    }
    Ok(())
}
